"""Observability (repro.obs): recording changes nothing, exports pin bytes.

The contract the whole subsystem hangs on: a :class:`~repro.obs.trace.
Recorder` / :class:`~repro.obs.profile.EngineProfile` attached to an
:class:`~repro.core.engine.EngineSession` is *observational* — every
scheduled float is bit-for-bit the unobserved one — and everything it
exports (Chrome trace JSON, metrics snapshots) is deterministic down to
the byte.  Plus the metric primitives' units and the serving summary's
small-sample honesty flags.
"""

import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro import obs
from repro.core import taskgraph
from repro.core.engine import BankModel, EngineSession, RefreshSpec
from repro.core.pluto import Interconnect
from repro.device import DeviceGeometry
from repro.device.batch import SweepConfig
from repro.device.resources import DeviceModel
from repro.obs.trace import record_sweep
from repro.runtime.serve import ServingRuntime, summarize
from repro.runtime.trace import TenantSpec, open_loop_trace

GEOM = DeviceGeometry(channels=1, banks_per_channel=4)
REFRESH = RefreshSpec(interval_ns=3900.0, duration_ns=350.0)


def device_graph(mode, app="pmm", **kw):
    from repro.device.partition import build_partitioned_ir
    return build_partitioned_ir(app, mode, GEOM, **(kw or dict(n=16)))


# --- recording is observational ---------------------------------------------------


class TestRecordingChangesNothing:
    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_recorded_stats_equal_plain(self, mode):
        g = device_graph(mode)
        plain = EngineSession(DeviceModel(mode, GEOM), refresh=REFRESH)
        plain.admit(g)
        plain.advance()
        rec = obs.Recorder()
        prof = obs.EngineProfile()
        observed = EngineSession(DeviceModel(mode, GEOM), refresh=REFRESH,
                                 recorder=rec, profile=prof)
        observed.admit(g)
        observed.advance()
        assert observed.stats() == plain.stats()

    def test_recorder_rejects_second_session(self):
        rec = obs.Recorder()
        EngineSession(BankModel(Interconnect.LISA), recorder=rec)
        with pytest.raises(ValueError, match="already attached"):
            EngineSession(BankModel(Interconnect.LISA), recorder=rec)


# --- trace structure --------------------------------------------------------------


class TestChromeTrace:
    @pytest.fixture(scope="class")
    def recorded(self):
        cfg = SweepConfig.make("mm", Interconnect.SHARED_PIM, GEOM, n=16)
        return record_sweep(cfg, refresh=REFRESH)

    def test_events_well_formed(self, recorded):
        doc = recorded.chrome_trace()
        assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
        makespan_us = recorded._session.stats().makespan_ns / 1e3
        for e in doc["traceEvents"]:
            assert e["ph"] in ("X", "i", "C", "M")
            if e["ph"] == "X":
                assert e["dur"] >= 0.0
                assert 0.0 <= e["ts"] <= e["ts"] + e["dur"] <= makespan_us

    def test_every_token_has_a_named_track(self, recorded):
        doc = recorded.chrome_trace()
        names = {(e["pid"], e.get("tid")): e["args"]["name"]
                 for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        model = recorded._session.model
        for tid, want in enumerate(model.token_names()):
            assert names[(0, tid)] == want
        n_res = len(model.token_names())
        for u, want in enumerate(model.refresh_unit_names()):
            assert names[(0, n_res + u)] == want
        # every X event on pid 0 lands on a named track
        for e in doc["traceEvents"]:
            if e["ph"] == "X" and e["pid"] == 0:
                assert (0, e["tid"]) in names

    def test_metadata_carries_provenance(self, recorded):
        doc = recorded.chrome_trace({"extra": 1})
        other = doc["otherData"]
        assert other["interconnect"] == "shared_pim"
        assert other["extra"] == 1
        (job,) = other["jobs"]
        assert job["n_tasks"] == recorded._session.job(0).n_tasks
        assert len(job["graph_fingerprint"]) == 16

    def test_refresh_tracks_present(self, recorded):
        doc = recorded.chrome_trace()
        n_res = len(recorded._session.model.token_names())
        refresh_events = [e for e in doc["traceEvents"]
                          if e["ph"] == "X" and e["pid"] == 0
                          and e["tid"] >= n_res]
        assert len(refresh_events) == len(recorded._refresh) > 0

    def test_utilization_fractions(self, recorded):
        util = obs.utilization(recorded)
        assert util
        for name, frac in util.items():
            assert 0.0 <= frac <= 1.0, name
        assert any(frac > 0.0 for frac in util.values())


class TestGraphFingerprint:
    def test_stable_and_sensitive(self):
        a = taskgraph.build_ir("mm", Interconnect.LISA, n=8)
        b = taskgraph.build_ir("mm", Interconnect.LISA, n=8)
        c = taskgraph.build_ir("mm", Interconnect.SHARED_PIM, n=8)
        assert obs.graph_fingerprint(a) == obs.graph_fingerprint(b)
        assert obs.graph_fingerprint(a) != obs.graph_fingerprint(c)


# --- byte determinism -------------------------------------------------------------


class TestDeterminism:
    def test_record_sweep_twice_byte_identical(self, tmp_path):
        cfg = SweepConfig.make("qwen2-moe-a2.7b", Interconnect.LISA,
                               DeviceGeometry(channels=1, banks_per_channel=4,
                                              pes_per_bank=8),
                               phase="decode", n_layers=2)
        pa = record_sweep(cfg, refresh=REFRESH).dump(tmp_path / "a.json")
        pb = record_sweep(cfg, refresh=REFRESH).dump(tmp_path / "b.json")
        assert pa.read_bytes() == pb.read_bytes()
        json.loads(pa.read_text())     # still valid JSON

    def test_serving_trace_byte_identical(self, tmp_path):
        tenants = [TenantSpec.make("a", "mm", rate_jps=2e5, banks=2, n=12),
                   TenantSpec.make("b", "mm", rate_jps=1e5, banks=1, n=8)]
        reqs = open_loop_trace(tenants, jobs_per_tenant=3, seed=3)

        def one(path):
            rt = ServingRuntime(Interconnect.SHARED_PIM, GEOM,
                                recorder=obs.Recorder(), refresh=REFRESH)
            rt.run(reqs)
            return rt.export_trace(path)

        pa = one(tmp_path / "a.json")
        pb = one(tmp_path / "b.json")
        assert pa.read_bytes() == pb.read_bytes()
        other = json.loads(pa.read_text())["otherData"]
        assert other["admission"] == "fifo"
        assert "rewrite_logs" in other


# --- metric primitives ------------------------------------------------------------


class TestMetricPrimitives:
    def test_counter_monotonic(self):
        c = obs.Counter()
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_series_and_time_weighted_mean(self):
        g = obs.Gauge()
        assert g.last is None and g.peak is None
        assert g.time_weighted_mean() == 0.0
        g.record(0.0, 2.0)
        g.record(10.0, 4.0)   # 2.0 held for the whole [0, 10) span
        assert g.last == 4.0 and g.peak == 4.0
        assert g.time_weighted_mean() == 2.0
        assert g.series() == [(0.0, 2.0), (10.0, 4.0)]

    def test_histogram_summary(self):
        h = obs.Histogram()
        assert h.summary() == {"n": 0, "reliable": False}
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        s = h.summary(percentiles=(50.0,))
        assert s["n"] == 4 and s["mean"] == 2.5
        assert s["min"] == 1.0 and s["max"] == 4.0 and s["p50"] == 2.5
        assert s["reliable"] is True

    def test_histogram_percentile_guards(self):
        h = obs.Histogram()
        # empty: no value, flagged unreliable -- never a raise or a NaN
        assert h.percentile(99.0) == (None, False)
        h.observe(7.0)
        v, reliable = h.percentile(99.0)
        assert v == 7.0 and reliable is False   # one sample: a constant
        h.observe(9.0)
        v, reliable = h.percentile(50.0)
        assert v == 8.0 and reliable is True

    def test_registry_create_on_first_use_and_snapshot(self):
        m = obs.MetricsRegistry()
        m.counter("x").inc()
        assert m.counter("x").value == 1       # same object back
        m.gauge("g").record(0.0, 1.0)
        m.histogram("h").observe(2.0)
        snap = m.snapshot()
        assert snap["counters"] == {"x": 1}
        assert snap["gauges"]["g"]["last"] == 1.0
        assert snap["histograms"]["h"]["n"] == 1

    def test_slo_attainment(self):
        rows = [dataclasses.make_dataclass("R", ["tenant", "latency_ns"])(t, v)
                for t, v in [("a", 5.0), ("a", 15.0), ("b", 1.0)]]
        att = obs.slo_attainment(rows, slo_ns=10.0)
        assert att["a"] == {"n_jobs": 2, "attained": 1, "attainment": 0.5}
        assert att["b"]["attainment"] == 1.0
        with pytest.raises(ValueError):
            obs.slo_attainment(rows, slo_ns=0.0)


# --- self-profiling ---------------------------------------------------------------


class TestEngineProfile:
    def test_counts_match_graph(self):
        mode = Interconnect.SHARED_PIM
        g = device_graph(mode)
        prof = obs.EngineProfile()
        s = EngineSession(DeviceModel(mode, GEOM), profile=prof)
        s.admit(g)
        s.advance()
        assert prof.n_advances == 1
        assert prof.n_exec == g.n
        summary = prof.summary()
        assert summary["heap_pops"] == g.n
        # every non-source task is pushed exactly once as its last
        # dependency retires; sources were pushed at admit time, before
        # the profiled advance
        n_sources = int((g.dep_indptr[1:] == g.dep_indptr[:-1]).sum())
        assert summary["heap_pushes"] == g.n - n_sources
        assert summary["token_probes"] > 0
        assert prof.events_per_sec > 0.0
        assert summary["refresh_windows"] == 0

    def test_empty_profile(self):
        prof = obs.EngineProfile()
        assert prof.events_per_sec == 0.0
        assert prof.summary()["token_probes_per_task"] == 0.0


# --- serving summary hardening ----------------------------------------------------


class FakeResult:
    def __init__(self, tenant, arrival, finish, admit=None):
        self.tenant = tenant
        self.arrival_ns = arrival
        self.admit_ns = arrival if admit is None else admit
        self.finish_ns = finish
        self.latency_ns = finish - arrival
        self.queue_ns = self.admit_ns - arrival


class TestSummarizePerTenant:
    def test_zero_samples(self):
        s = summarize([])
        assert s["per_tenant"] == {} and s["n_jobs"] == 0
        assert s["percentile_min_samples"] == 2

    def test_one_sample_flagged_unreliable(self):
        s = summarize([FakeResult("t", 0.0, 10.0)])
        row = s["per_tenant"]["t"]
        assert row["n_jobs"] == 1 and row["mean_ns"] == 10.0
        assert row["p99_ns"] == 10.0 and row["p99_reliable"] is False

    def test_two_samples_reliable_at_default_threshold(self):
        s = summarize([FakeResult("t", 0.0, 10.0),
                       FakeResult("t", 0.0, 20.0)])
        row = s["per_tenant"]["t"]
        assert row["n_jobs"] == 2 and row["p99_reliable"] is True
        assert row["mean_ns"] == 15.0

    def test_min_samples_validation_and_threshold(self):
        with pytest.raises(ValueError):
            summarize([], min_samples=0)
        s = summarize([FakeResult("t", 0.0, 10.0),
                       FakeResult("t", 0.0, 20.0)], min_samples=3)
        assert s["per_tenant"]["t"]["p99_reliable"] is False


# --- module entry point -----------------------------------------------------------


@pytest.mark.slow
def test_obs_module_entry_smoke(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(Path(__file__).resolve().parent.parent
                               / "src")})
    assert proc.returncode == 0, proc.stderr
    assert "ui.perfetto.dev" in proc.stdout
    written = sorted(p.name for p in tmp_path.glob("*.trace.json"))
    assert len(written) == 4 and "moe-decode.lisa.trace.json" in written
