"""MoE dispatch invariants: sort-based capacity dispatch vs dense oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import hypothesis, st  # noqa: F401

from repro.configs import registry
from repro.models import layers, moe


def _cfg(E=8, k=2, d=16, f=32, shared=0):
    base = registry.get("qwen2-moe-a2.7b").reduced()
    return dataclasses.replace(base, n_experts=E, n_experts_active=k,
                               moe_d_ff=f, d_model=d,
                               shared_expert_d_ff=shared)


def _dense_oracle(params, x, cfg):
    """Route with the same top-k, but compute EVERY expert densely."""
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        params["router"])
    w, experts = jax.lax.top_k(jax.nn.softmax(logits, -1),
                               cfg.n_experts_active)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    g = layers._act(jnp.einsum("nd,edf->enf", xf, params["wi_gate"]),
                    cfg.act)
    u = jnp.einsum("nd,edf->enf", xf, params["wi_up"])
    all_out = jnp.einsum("enf,efd->end", g * u, params["wo"])  # (E, N, d)
    out = jnp.zeros((N, d), x.dtype)
    for j in range(cfg.n_experts_active):
        sel = jnp.take_along_axis(
            all_out.transpose(1, 0, 2), experts[:, j][:, None, None],
            axis=1)[:, 0]
        out = out + sel * w[:, j][:, None].astype(x.dtype)
    return out.reshape(B, T, d)


class TestMoE:
    def test_matches_dense_oracle_no_drops(self):
        cfg = _cfg()
        params = moe.init_moe_params(jax.random.key(0), cfg.d_model, cfg,
                                     jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, 12, cfg.d_model)), jnp.float32)
        got = moe.moe_block(params, x, cfg, capacity_factor=100.0)
        want = _dense_oracle(params, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_capacity_drops_reduce_norm_not_nan(self):
        cfg = _cfg(E=4, k=2)
        params = moe.init_moe_params(jax.random.key(1), cfg.d_model, cfg,
                                     jnp.float32)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
        full = moe.moe_block(params, x, cfg, capacity_factor=100.0)
        tight = moe.moe_block(params, x, cfg, capacity_factor=0.25)
        assert bool(jnp.isfinite(tight).all())
        assert float(jnp.linalg.norm(tight)) <= \
            float(jnp.linalg.norm(full)) + 1e-3

    def test_shared_expert_added(self):
        cfg = _cfg(shared=64)
        params = moe.init_moe_params(jax.random.key(2), cfg.d_model, cfg,
                                     jnp.float32)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)
        with_shared = moe.moe_block(params, x, cfg, capacity_factor=100.0)
        shared_only = layers.mlp_block(params["shared"], x, cfg.act)
        routed = _dense_oracle(params, x, cfg)
        np.testing.assert_allclose(np.asarray(with_shared),
                                   np.asarray(routed + shared_only),
                                   rtol=2e-4, atol=2e-5)

    @hypothesis.given(st.integers(0, 10_000))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_property_random_routing(self, seed):
        cfg = _cfg(E=6, k=3, d=8, f=16)
        params = moe.init_moe_params(jax.random.key(seed % 97), cfg.d_model,
                                     cfg, jnp.float32)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(1, 10, cfg.d_model)), jnp.float32)
        got = moe.moe_block(params, x, cfg, capacity_factor=100.0)
        want = _dense_oracle(params, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-5)
