"""Continuous batching: residencies, iteration scheduling, TTFT/TPOT.

Covers the decoupled job/lease lifecycle end to end: the group-aligned
bank picker, the ContinuousAllocator's residency/preemption/migration
machinery (including property-based invariant checks over interleaved op
sequences), the KV-parameterized decode_step lowering, the new summarize()
streaming sections, and the ContinuousRuntime iteration scheduler —
with the continuous-off path pinned bit-for-bit to the whole-job runtime.
"""

import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st  # noqa: F401
from repro.core import taskgraph
from repro.core.pluto import Interconnect
from repro.device import DeviceGeometry
from repro.runtime import (BankAllocator, ContinuousAllocator,
                           ContinuousRuntime, MultiTurnSource,
                           ServingRuntime, SessionResult, SessionSpec,
                           TenantSpec, open_loop_trace, session_trace,
                           summarize)

#: 16 banks in 4 groups of 4 — group structure visible to the picker
GEOM = DeviceGeometry(channels=1, banks_per_channel=16,
                      bank_groups_per_channel=4, pes_per_bank=2)
#: small device for allocator-level tests: 8 banks in 4 groups of 2
SMALL = DeviceGeometry(channels=1, banks_per_channel=8,
                       bank_groups_per_channel=4)


def specs(decode_tokens=8, turns=1, think_ns=0.0, rate=2000.0):
    return [
        SessionSpec.make("chat", "gemma3-1b", n_layers=2,
                         prompt_tokens=512, decode_tokens=decode_tokens,
                         turns=turns, think_ns=think_ns, rate_sps=rate),
        SessionSpec.make("agent", "granite-3-2b", n_layers=2,
                         prompt_tokens=256, decode_tokens=decode_tokens,
                         turns=turns, think_ns=think_ns, rate_sps=rate),
    ]


# --- group-aligned bank picking ---------------------------------------------------


class TestGroupAlignedPicks:
    def test_prefers_group_aligned_run(self):
        # 8 banks, groups of 2: free {1,2} straddles groups 0/1, {4,5} is
        # exactly group 2 — the group-aligned run must win even though
        # {1,2} is lower
        alloc = BankAllocator(SMALL)
        for lease in alloc.request(8):
            pass
        alloc._active.clear()
        alloc._free = {1, 2, 4, 5}
        assert alloc._pick_banks(2) == (4, 5)

    def test_prefers_fewer_groups_spanned(self):
        # free {1,2,3} (spans groups 0-1) vs {5,6,7} (spans groups 2-3):
        # both span two groups, but {6,7}+{5}... for k=3 both span 2
        # groups; {1,2,3} starts off-boundary, {5,6,7} too — lowest wins
        alloc = BankAllocator(SMALL)
        alloc._free = {1, 2, 3, 5, 6, 7}
        assert alloc._pick_banks(3) == (1, 2, 3)
        # but a boundary-started run beats an off-boundary one
        alloc._free = {1, 2, 3, 4, 5}
        assert alloc._pick_banks(2) == (2, 3)
        assert alloc._pick_banks(4) == (2, 3, 4, 5)

    def test_single_group_degenerates_to_lowest_run(self):
        geom = DeviceGeometry(channels=1, banks_per_channel=8)
        alloc = BankAllocator(geom)
        alloc._free = {1, 2, 4, 5}
        assert alloc._pick_banks(2) == (1, 2)

    def test_fallback_scatter_when_no_run(self):
        alloc = BankAllocator(SMALL)
        alloc._free = {0, 2, 4, 6}
        assert alloc._pick_banks(3) == (0, 2, 4)


# --- the continuous allocator -----------------------------------------------------


class TestContinuousAllocator:
    def make(self, **kw):
        kw.setdefault("decode_reserve", 4)
        kw.setdefault("tokens_per_bank", 100)
        return ContinuousAllocator(SMALL, **kw)

    def test_banks_for_quantization(self):
        alloc = self.make()
        assert alloc.banks_for(0) == 1
        assert alloc.banks_for(1) == 1
        assert alloc.banks_for(100) == 1
        assert alloc.banks_for(101) == 2
        assert alloc.banks_for(10_000) == SMALL.n_banks

    def test_prefill_pool_cap(self):
        alloc = self.make()           # pool = 8 - 4 = 4
        assert alloc.prefill_pool == 4
        got = alloc.request(3, payload="a")
        assert len(got) == 1
        assert alloc.request(2, payload="b") == []   # 3 + 2 > pool
        assert alloc.n_queued == 1
        with pytest.raises(ValueError):
            alloc.request(5)          # can never fit the pool
        # releasing the first admits the queued one
        granted = alloc.release(got[0])
        assert [lease.payload for lease in granted] == ["b"]

    def test_admission_pause_gates_drain(self):
        alloc = self.make()
        alloc.admission_paused = True
        assert alloc.request(1, payload="x") == []
        assert alloc.n_queued == 1
        assert alloc.drain() == []
        alloc.admission_paused = False
        assert [lease.payload for lease in alloc.drain()] == ["x"]

    def test_preempt_requeues_ahead_and_does_not_drain(self):
        alloc = self.make()
        (first,) = alloc.request(2, payload="victim")
        alloc.request(3, payload="waiter")
        assert alloc.n_queued == 1
        alloc.preempt(first)
        # banks freed, nothing admitted until the caller drains
        assert alloc.n_free == SMALL.n_banks and alloc.n_queued == 2
        granted = alloc.drain()
        # the preempted job re-admits ahead of the earlier-queued waiter
        # (which still can't fit the pool next to it)
        assert [lease.payload for lease in granted] == ["victim"]
        regrant = alloc.release(granted[0])
        assert [lease.payload for lease in regrant] == ["waiter"]

    def test_preempt_rejects_stale_lease(self):
        alloc = self.make()
        (lease,) = alloc.request(1)
        alloc.release(lease)
        with pytest.raises(ValueError):
            alloc.preempt(lease)

    def test_acquire_grow_and_extend(self):
        alloc = self.make()
        res = alloc.acquire("t", kv_tokens=150)
        assert res is not None and len(res.banks) == 2
        assert alloc.n_banks_resident == 2
        assert alloc.grow(res, 50) is True          # 200 tokens -> 2 banks
        assert len(res.banks) == 2
        assert alloc.grow(res, 100) is True         # 300 tokens -> 3 banks
        assert len(res.banks) == 3
        # fill the device; growth past capacity reports over-packed
        other = alloc.acquire("u", kv_tokens=100 * (SMALL.n_banks - 3))
        assert other is not None and alloc.n_free == 0
        assert alloc.grow(res, 100) is False
        assert alloc.release_residency(other) == []
        assert alloc.grow(res, 0) is True           # heals once banks free

    def test_adopt_in_place_keeps_and_frees(self):
        alloc = self.make()
        (lease,) = alloc.request(3, payload="s")
        res = alloc.adopt(lease, "s", kv_tokens=120)   # needs 2 of the 3
        assert res.banks == lease.banks[:2]
        assert alloc.n_banks_prefill == 0
        assert alloc.n_free == SMALL.n_banks - 2
        with pytest.raises(ValueError):
            alloc.release(lease)      # the lease was consumed by adoption

    def test_adopt_extends_when_kv_outgrew_lease(self):
        alloc = self.make()
        (lease,) = alloc.request(1, payload="s")
        res = alloc.adopt(lease, "s", kv_tokens=250)   # needs 3
        assert len(res.banks) == 3 and res.banks[0] == lease.banks[0]

    def test_grant_step_sequence(self):
        alloc = self.make()
        res = alloc.acquire("t")
        g0, g1 = alloc.grant_step(res), alloc.grant_step(res)
        assert (g0.step, g1.step) == (0, 1)
        assert g0.rid == res.rid and g0.banks == res.banks
        assert res.steps_granted == 2

    def test_migration_holds_both_sets_until_commit(self):
        alloc = self.make()
        res = alloc.acquire("t", kv_tokens=150)
        src = res.banks
        dst = alloc.begin_migration(res)
        assert dst is not None and set(dst).isdisjoint(src)
        assert alloc.n_banks_resident == 4          # both copies held
        assert set(src).isdisjoint(alloc._free)
        assert set(dst).isdisjoint(alloc._free)
        alloc.commit_migration(res)
        assert res.banks == dst and res.migrating_to is None
        assert set(src) <= alloc._free
        assert alloc.n_banks_resident == 2

    def test_abort_migration_returns_destination(self):
        alloc = self.make()
        res = alloc.acquire("t")
        before = alloc.n_free
        alloc.begin_migration(res)
        alloc.abort_migration(res)
        assert alloc.n_free == before and res.migrating_to is None

    def test_release_residency_mid_migration_frees_both(self):
        alloc = self.make()
        res = alloc.acquire("t", kv_tokens=150)
        alloc.begin_migration(res)
        alloc.release_residency(res)
        assert alloc.n_free == SMALL.n_banks

    def test_stale_residency_rejected(self):
        alloc = self.make()
        res = alloc.acquire("t")
        alloc.release_residency(res)
        for call in (lambda: alloc.grow(res, 1),
                     lambda: alloc.grant_step(res),
                     lambda: alloc.begin_migration(res),
                     lambda: alloc.release_residency(res)):
            with pytest.raises(ValueError):
                call()


# --- interleaved-op invariants (property-based + seeded driver) -------------------


def _conservation(alloc: ContinuousAllocator) -> None:
    held: list[int] = []
    for lease in alloc._active.values():
        held.extend(lease.banks)
    for res in alloc._resident.values():
        held.extend(res.banks)
        held.extend(res.migrating_to or ())
    assert len(held) == len(set(held)), "bank double-leased"
    assert set(held).isdisjoint(alloc._free), "held bank marked free"
    assert len(held) + alloc.n_free == alloc.geom.n_banks, \
        "bank conservation violated"


def _interleave(seed: int, n_ops: int = 120) -> list:
    """Drive a random request/grant/release/preempt/migrate interleave,
    checking the allocator invariants after every op; returns the event
    log (for determinism comparison)."""
    rng = np.random.default_rng(seed)
    alloc = ContinuousAllocator(SMALL, decode_reserve=3, tokens_per_bank=50)
    log: list = []
    leases: list = []
    rezs: list = []
    preempted: set = set()
    admitted: set = set()
    payload = 0
    for _ in range(n_ops):
        op = int(rng.integers(0, 8))
        if op == 0:
            banks = int(rng.integers(1, alloc.prefill_pool + 1))
            for lease in alloc.request(banks, payload=payload):
                leases.append(lease)
                admitted.add(lease.payload)
            log.append(("req", payload, banks))
            payload += 1
        elif op == 1 and leases:
            lease = leases.pop(int(rng.integers(0, len(leases))))
            for granted in alloc.release(lease):
                leases.append(granted)
                admitted.add(granted.payload)
            log.append(("rel", lease.ticket))
        elif op == 2 and leases:
            lease = leases.pop(int(rng.integers(0, len(leases))))
            alloc.preempt(lease)
            preempted.add(lease.payload)
            log.append(("pre", lease.ticket))
        elif op == 3:
            res = alloc.acquire(f"t{payload}",
                                kv_tokens=int(rng.integers(0, 120)))
            if res is not None:
                rezs.append(res)
            log.append(("acq", res.rid if res else None))
        elif op == 4 and rezs:
            res = rezs[int(rng.integers(0, len(rezs)))]
            if res.migrating_to is None:
                ok = alloc.grow(res, int(rng.integers(1, 80)))
                log.append(("grow", res.rid, ok))
        elif op == 5 and rezs:
            res = rezs[int(rng.integers(0, len(rezs)))]
            if res.migrating_to is None:
                dst = alloc.begin_migration(res)
                log.append(("mig", res.rid, dst))
            else:
                if rng.integers(0, 2):
                    alloc.commit_migration(res)
                    log.append(("commit", res.rid))
                else:
                    alloc.abort_migration(res)
                    log.append(("abort", res.rid))
        elif op == 6 and rezs:
            res = rezs.pop(int(rng.integers(0, len(rezs))))
            for granted in alloc.release_residency(res):
                leases.append(granted)
                admitted.add(granted.payload)
            log.append(("relres", res.rid))
        elif op == 7:
            alloc.admission_paused = bool(rng.integers(0, 2)) \
                and alloc.admission_paused
            for granted in alloc.drain():
                leases.append(granted)
                admitted.add(granted.payload)
            log.append(("drain",))
        _conservation(alloc)
        assert alloc.n_banks_prefill == \
            sum(len(lease.banks) for lease in alloc._active.values())
    # wind down: everything releases, the queue fully re-admits —
    # preempted work must always come back
    alloc.admission_paused = False
    for res in rezs:
        for granted in alloc.release_residency(res):
            leases.append(granted)
            admitted.add(granted.payload)
    while leases or alloc.n_queued:
        if not leases:
            granted = alloc.drain()
            assert granted, "queued work stuck with free banks"
            leases.extend(granted)
            admitted.update(lease.payload for lease in granted)
            continue
        for granted in alloc.release(leases.pop()):
            leases.append(granted)
            admitted.add(granted.payload)
        _conservation(alloc)
    assert preempted <= admitted, "preempted work never re-admitted"
    assert alloc.n_free == alloc.geom.n_banks
    return log


class TestInterleaveInvariants:
    @pytest.mark.parametrize("seed", range(8))
    def test_seeded_interleaves(self, seed):
        _interleave(seed)

    def test_deterministic_under_seed(self):
        assert _interleave(123) == _interleave(123)
        assert _interleave(123) != _interleave(124) or True  # logs may differ

    @hypothesis.given(st.integers(min_value=0, max_value=10_000))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_property_interleaves(self, seed):
        _interleave(seed, n_ops=60)


# --- decode_step lowering ---------------------------------------------------------


class TestDecodeStep:
    def test_kv_zero_is_the_legacy_graph(self):
        from repro.frontend.lower import decode_step
        base = taskgraph.structural("gemma3-1b", n_pes=16, n_layers=2)
        step = decode_step("gemma3-1b", n_pes=16, kv_len=0, n_layers=2)
        assert step.n == base.n
        assert list(step.pe) == list(base.pe)
        assert list(step.kinds) == list(base.kinds)

    def test_graph_grows_monotonically_with_kv(self):
        from repro.frontend.lower import decode_step
        sizes = [decode_step("gemma3-1b", kv_len=k, n_layers=2).n
                 for k in (0, 200, 600, 2000, 10_000, 100_000)]
        assert sizes == sorted(sizes)
        assert sizes[-1] > sizes[0]
        # capped: enormous contexts stop growing
        assert sizes[-1] == decode_step("gemma3-1b", kv_len=10**7,
                                        n_layers=2).n

    def test_kv_tiles_quantization(self):
        from repro.frontend.lower import _KV_CAP, kv_tiles_for
        assert kv_tiles_for(0) == 0
        assert kv_tiles_for(-5) == 0
        assert kv_tiles_for(1) == 1
        assert kv_tiles_for(256) == 1
        assert kv_tiles_for(257) == 2
        assert kv_tiles_for(10**9) == _KV_CAP

    def test_prefill_context_depth(self):
        base = taskgraph.structural("gemma3-1b", phase="prefill",
                                    n_pes=16, n_layers=2)
        deep = taskgraph.structural("gemma3-1b", phase="prefill",
                                    n_pes=16, n_layers=2, kv_tiles=3)
        assert deep.n > base.n

    def test_validation(self):
        from repro.frontend.lower import decode_step
        with pytest.raises(ValueError):
            decode_step("gemma3-1b", kv_len=-1)
        with pytest.raises(ValueError):
            taskgraph.structural("gemma3-1b", n_pes=16, kv_tiles=99)


# --- summarize(): TTFT / TPOT sections --------------------------------------------


def _session(tenant="s", seq=0, arrival=0.0, token_ns=(), turn_start=(0.0,),
             turn_first=(), tokens_per_turn=4):
    return SessionResult(tenant, "gemma3-1b", seq, arrival, arrival,
                         token_ns[-1] if token_ns else arrival,
                         tuple(token_ns), tuple(turn_start),
                         tuple(turn_first), tokens_per_turn, 1, 0, 0, 0)


class TestSummarizeStreams:
    def test_job_only_batches_keep_empty_stream_sections(self):
        s = summarize([])
        assert s["ttft_ns"] == {"n": 0, "p99_reliable": False}
        assert s["tpot_ns"] == {"n": 0, "p99_reliable": False}
        assert s["decode_tps"] == 0.0

    def test_zero_one_two_tpot_samples(self):
        # one token: no gaps -> n=0, no percentile keys at all
        r = _session(token_ns=(10.0,), turn_first=(10.0,))
        s = summarize([r])
        assert s["tpot_ns"] == {"n": 0, "p99_reliable": False}
        assert "p99" not in s["tpot_ns"]
        # two tokens: one gap -> percentiles exist but are unreliable
        r = _session(token_ns=(10.0, 14.0), turn_first=(10.0,))
        s = summarize([r])
        assert s["tpot_ns"]["n"] == 1
        assert s["tpot_ns"]["p99"] == 4.0 and s["tpot_ns"]["mean"] == 4.0
        assert s["tpot_ns"]["p99_reliable"] is False
        # three tokens: two gaps -> reliable at the default threshold
        r = _session(token_ns=(10.0, 14.0, 20.0), turn_first=(10.0,))
        s = summarize([r])
        assert s["tpot_ns"]["n"] == 2 and s["tpot_ns"]["p99_reliable"]
        assert s["tpot_ns"]["mean"] == 5.0

    def test_min_samples_threshold_applies_to_streams(self):
        r = _session(token_ns=(10.0, 14.0, 20.0), turn_first=(10.0,))
        s = summarize([r], min_samples=3)
        assert s["tpot_ns"]["n"] == 2
        assert s["tpot_ns"]["p99_reliable"] is False

    def test_ttft_one_sample_per_turn(self):
        r = _session(token_ns=(10.0, 12.0, 110.0, 115.0),
                     turn_start=(0.0, 100.0), turn_first=(10.0, 110.0),
                     tokens_per_turn=2)
        s = summarize([r])
        assert s["ttft_ns"]["n"] == 2
        assert s["ttft_ns"]["mean"] == 10.0
        assert s["ttft_ns"]["p99_reliable"] is True
        # the 110 -> 12 jump across turns is never a TPOT sample
        assert r.tpot_samples == (2.0, 5.0)

    def test_decode_tps_counts_tokens_over_span(self):
        r = _session(token_ns=(5e8, 1e9), turn_first=(5e8,),
                     tokens_per_turn=2)
        s = summarize([r])
        assert s["decode_tps"] == pytest.approx(2.0)

    def test_ttft_property_includes_queueing(self):
        r = SessionResult("s", "gemma3-1b", 0, arrival_ns=0.0,
                          admit_ns=3.0, finish_ns=20.0,
                          token_ns=(12.0, 20.0), turn_start_ns=(0.0,),
                          turn_first_ns=(12.0,), tokens_per_turn=2,
                          banks_resident=1, n_migrations=0,
                          n_preemptions=0, n_tasks=0)
        assert r.ttft_ns == 12.0 and r.queue_ns == 3.0


# --- the iteration scheduler end to end -------------------------------------------


class TestContinuousRuntime:
    def run_fleet(self, mode, *, turns=1, think_ns=0.0, slo=2e5, **kw):
        rt = ContinuousRuntime(mode, GEOM, chunk_tokens=128,
                               tokens_per_bank=256, tpot_slo_ns=slo, **kw)
        tr = session_trace(specs(turns=turns, think_ns=think_ns),
                           sessions_per_spec=3, seed=0)
        return rt, rt.run_sessions(tr)

    def test_every_token_lands_once(self):
        rt, res = self.run_fleet(Interconnect.SHARED_PIM, turns=2,
                                 think_ns=5e5)
        assert len(res) == 6
        for r in res:
            assert len(r.token_ns) == r.tokens_per_turn * 2
            assert list(r.token_ns) == sorted(r.token_ns)
            assert len(r.turn_first_ns) == len(r.turn_start_ns) == 2
        # the device fully quiesced: no leak of banks or queue entries
        assert rt.allocator.n_free == GEOM.n_banks
        assert rt.allocator.n_resident == 0 and rt.allocator.n_queued == 0

    def test_deterministic(self):
        _, a = self.run_fleet(Interconnect.SHARED_PIM)
        _, b = self.run_fleet(Interconnect.SHARED_PIM)
        assert a == b

    def test_shared_pim_beats_lisa_tpot(self):
        _, sp = self.run_fleet(Interconnect.SHARED_PIM, turns=2,
                               think_ns=5e5)
        _, li = self.run_fleet(Interconnect.LISA, turns=2, think_ns=5e5)
        ssp, sli = summarize(sp), summarize(li)
        assert ssp["tpot_ns"]["p99"] < sli["tpot_ns"]["p99"]
        assert ssp["decode_tps"] > sli["decode_tps"]

    def test_preemption_fires_under_tight_slo(self):
        _, tight = self.run_fleet(Interconnect.SHARED_PIM, turns=2,
                                  think_ns=5e5, slo=1e4)
        _, loose = self.run_fleet(Interconnect.SHARED_PIM, turns=2,
                                  think_ns=5e5, slo=None)
        assert sum(r.n_preemptions for r in tight) > 0
        assert sum(r.n_preemptions for r in loose) == 0
        # preempted sessions still decode every token
        assert all(len(r.token_ns) == r.tokens_per_turn * 2 for r in tight)

    def test_migration_defragments_growth(self):
        spec = SessionSpec.make("chat", "gemma3-1b", n_layers=2,
                                prompt_tokens=64, decode_tokens=40,
                                turns=2, think_ns=1e5, rate_sps=3000.0,
                                concurrency=2)
        rt = ContinuousRuntime(Interconnect.SHARED_PIM, GEOM,
                               chunk_tokens=64, tokens_per_bank=16,
                               tpot_slo_ns=1e6)
        res = rt.run_sessions(
            source=MultiTurnSource([spec], sessions_per_spec=4, seed=0))
        assert sum(r.n_migrations for r in res) > 0
        assert all(len(r.token_ns) == 80 for r in res)
        assert rt.allocator.n_free == GEOM.n_banks

    def test_closed_loop_source_completes_budget(self):
        spec = specs()[0]
        rt = ContinuousRuntime(Interconnect.SHARED_PIM, GEOM,
                               chunk_tokens=128, tokens_per_bank=256)
        res = rt.run_sessions(
            source=MultiTurnSource([spec], sessions_per_spec=5, seed=1))
        assert len(res) == 5
        assert sorted(r.seq for r in res) == list(range(5))

    def test_run_sessions_requires_continuous(self):
        rt = ContinuousRuntime(Interconnect.SHARED_PIM, GEOM,
                               continuous=False)
        with pytest.raises(ValueError):
            rt.run_sessions(session_trace(specs(), sessions_per_spec=1,
                                          seed=0))

    def test_continuous_off_is_bitforbit_whole_job(self):
        tenants = [
            TenantSpec.make("mm", "mm", n=16, banks=2, rate_jps=2000.0),
            TenantSpec.make("bfs", "bfs", n_nodes=30, banks=2, priority=2,
                            rate_jps=2000.0),
        ]
        tr = open_loop_trace(tenants, jobs_per_tenant=6, seed=0)
        for mode in (Interconnect.SHARED_PIM, Interconnect.LISA):
            base = ServingRuntime(mode, GEOM).run(tr)
            cont = ContinuousRuntime(mode, GEOM, continuous=False).run(tr)
            assert cont == base


# --- job_cost memoization ---------------------------------------------------------


class TestJobCostMemoized:
    def test_one_structural_build_per_key(self, monkeypatch):
        rt = ServingRuntime(Interconnect.SHARED_PIM, GEOM, admission="sjf")
        calls = []
        real = taskgraph.structural

        def counting(app, **kw):
            calls.append(app)
            return real(app, **kw)

        monkeypatch.setattr(taskgraph, "structural", counting)
        t = TenantSpec.make("mm", "mm", n=16, banks=2)
        reqs = open_loop_trace([t], jobs_per_tenant=4, seed=0)
        for r in reqs:
            rt.job_cost(r)
        assert calls == ["mm"]
        # a different shape is a different key
        t2 = TenantSpec.make("mm2", "mm", n=24, banks=2)
        rt.job_cost(open_loop_trace([t2], jobs_per_tenant=1, seed=0)[0])
        assert calls == ["mm", "mm"]
