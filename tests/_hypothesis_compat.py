"""Hypothesis import shim: property-based tests skip when hypothesis is absent.

Not every runtime ships ``hypothesis``.  A bare ``import hypothesis`` makes
the whole module fail collection, and ``pytest.importorskip("hypothesis")``
at module scope would skip the example-based tests in the same file too.
Importing through this module instead keeps those runnable: when hypothesis
is missing, ``@hypothesis.given`` becomes a skip marker and the strategy
namespace becomes an inert chainable stub (it is only touched at decoration
time, never executed).
"""

from __future__ import annotations

import types

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable stand-in for strategy objects (never executed)."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies("hypothesis.strategies")

    def _given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install hypothesis)")(fn)
        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    hypothesis = types.SimpleNamespace(
        given=_given, settings=_settings, strategies=st)
