"""Model-inference frontend: lowering, registration, and serving."""

import pytest

from repro.configs import registry
from repro.core import ir, taskgraph
from repro.core.engine import EngineSession
from repro.core.pluto import Interconnect
from repro.device import DeviceGeometry, DeviceModel, partition
from repro.device import scheduler as dev_sched
from repro import frontend
from repro.frontend import MODEL_APPS, MODEL_PHASES, lower, model_struct
from repro.runtime import ServingRuntime, TenantSpec, open_loop_trace, \
    summarize

GEOM = DeviceGeometry(channels=1, banks_per_channel=4)


class TestLowering:
    @pytest.mark.parametrize("arch", sorted(MODEL_APPS))
    @pytest.mark.parametrize("phase", MODEL_PHASES)
    def test_every_arch_lowers_and_validates(self, arch, phase):
        g = model_struct(arch, phase=phase, n_pes=32, n_layers=2)
        g.validate()
        assert g.n > 0
        # structural: ops are symbolic, durations unmaterialized
        assert (g.op_class[g.kinds == ir.OP] >= 0).all()
        assert (g.duration == 0.0).all()

    def test_decode_is_narrower_than_prefill(self):
        for arch in ("gemma3-1b", "qwen2-moe-a2.7b", "falcon-mamba-7b"):
            dec = model_struct(arch, phase="decode", n_pes=32, n_layers=2)
            pre = model_struct(arch, phase="prefill", n_pes=32, n_layers=2)
            assert dec.n < pre.n

    def test_depth_scales_with_n_layers(self):
        a = model_struct("gemma3-1b", phase="decode", n_pes=32, n_layers=2)
        b = model_struct("gemma3-1b", phase="decode", n_pes=32, n_layers=4)
        assert a.n < b.n

    def test_memoized_per_shape(self):
        a = model_struct("gemma3-1b", phase="decode", n_pes=32, n_layers=2)
        b = model_struct("gemma3-1b", phase="decode", n_pes=32, n_layers=2)
        assert a is b

    def test_default_layer_count_is_the_configs(self):
        cfg = registry.get("gemma3-1b")
        g = lower(cfg, "decode", n_pes=32)
        g2 = lower(cfg, "decode", n_pes=32, n_layers=cfg.n_layers)
        assert g.n == g2.n

    def test_moe_layers_fan_out_to_experts(self):
        cfg = registry.get("qwen2-moe-a2.7b")
        g = lower(cfg, "prefill", n_pes=32, n_layers=1, seq_tiles=1)
        tags = set(g.tags)
        for e in range(cfg.n_experts_active):
            assert any(f".exp{e}." in t for t in tags)
        assert any(".shexp." in t for t in tags)
        assert any(".combine." in t for t in tags)

    def test_ssm_layers_emit_scan_chains(self):
        g = lower(registry.get("falcon-mamba-7b"), "prefill", n_pes=32,
                  n_layers=1, seq_tiles=3)
        tags = g.tags
        assert any(".ssm.scan" in t for t in tags)
        # the recurrence carries state tile to tile in prefill
        assert any(".ssm.carry" in t for t in tags)

    def test_hybrid_mixes_attention_and_ssm(self):
        cfg = registry.get("zamba2-2.7b")
        g = lower(cfg, "decode", n_pes=32, n_layers=cfg.attn_every)
        tags = g.tags
        assert any(".ssm." in t for t in tags)
        assert any(".qkv." in t for t in tags)

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError, match="phase"):
            model_struct("gemma3-1b", phase="train")
        with pytest.raises(ValueError, match="arch"):
            model_struct("not-a-model")
        with pytest.raises(ValueError, match="n_layers"):
            model_struct("gemma3-1b", n_layers=0)
        with pytest.raises(ValueError, match="seq_tiles"):
            model_struct("gemma3-1b", seq_tiles=0)
        with pytest.raises(ValueError, match="n_pes"):
            lower(registry.get("gemma3-1b"), "decode", n_pes=0)


class TestRegistration:
    def test_registered_alongside_builtin_apps(self):
        known = taskgraph.known_apps()
        assert set(taskgraph.APPS) <= set(known)
        assert set(MODEL_APPS) <= set(known)

    def test_structural_dispatches_model_apps(self):
        g = taskgraph.structural("gemma3-1b", phase="decode", n_pes=32,
                                 n_layers=2)
        assert g is model_struct("gemma3-1b", phase="decode", n_pes=32,
                                 n_layers=2)

    def test_structural_unknown_app_raises(self):
        with pytest.raises(ValueError, match="unknown app"):
            taskgraph.structural("not-an-app")

    def test_builtins_cannot_be_clobbered(self):
        with pytest.raises(ValueError, match="builtin"):
            taskgraph.register_app("mm", lambda: None, ())

    def test_register_requires_cache_clear(self):
        with pytest.raises(ValueError, match="cache_clear"):
            taskgraph.register_app("some-model", lambda: None, ())

    def test_cannot_overwrite_registered_app(self):
        def fn(**kw):
            return None
        fn.cache_clear = lambda: None
        with pytest.raises(ValueError, match="already registered"):
            taskgraph.register_app("gemma3-1b", fn, ())

    def test_register_is_idempotent(self):
        before = taskgraph.known_apps()
        frontend.register()
        assert taskgraph.known_apps() == before

    def test_clear_caches_covers_model_builders(self):
        from repro.device import batch

        g = model_struct("gemma3-1b", phase="decode", n_pes=32, n_layers=2)
        batch.clear_caches()
        assert model_struct("gemma3-1b", phase="decode", n_pes=32,
                            n_layers=2) is not g

    def test_tenant_spec_accepts_model_apps(self):
        t = TenantSpec.make("chat", "gemma3-1b", phase="decode", n_layers=2)
        assert t.kwargs == {"phase": "decode", "n_layers": 2}
        with pytest.raises(ValueError, match="unknown app"):
            TenantSpec.make("bad", "gemma99-zz")

    def test_materialize_prices_both_modes(self):
        g = model_struct("granite-3-2b", phase="decode", n_pes=32,
                         n_layers=2)
        lisa = ir.materialize(g, Interconnect.LISA)
        sp = ir.materialize(g, Interconnect.SHARED_PIM)
        ops = g.kinds == ir.OP
        assert (lisa.duration[ops] > 0).all()
        assert (sp.duration[ops] > 0).all()


class TestModelServing:
    def tenants(self):
        return [
            TenantSpec.make("chat", "gemma3-1b", phase="decode", n_layers=2,
                            banks=1, rate_jps=2000.0, priority=2),
            TenantSpec.make("bulk", "qwen2-moe-a2.7b", phase="prefill",
                            n_layers=2, seq_tiles=2, banks=2,
                            rate_jps=500.0),
            TenantSpec.make("mamba", "falcon-mamba-7b", phase="decode",
                            n_layers=2, banks=1, rate_jps=1500.0),
        ]

    def test_lease_confines_model_graph(self):
        g = taskgraph.structural("gemma3-1b", phase="decode",
                                 n_pes=2 * GEOM.pes_per_bank, n_layers=2)
        placed = partition.place_on_banks(g, GEOM, (1, 3))
        ppb = GEOM.pes_per_bank
        pes = set(placed.pe[placed.pe >= 0].tolist()) \
            | set(placed.src[placed.src >= 0].tolist()) \
            | set(placed.dst_flat.tolist())
        assert {p // ppb for p in pes} <= {1, 3}

    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_serves_model_fleet_to_completion(self, mode):
        tr = open_loop_trace(self.tenants(), jobs_per_tenant=4, seed=0)
        res = ServingRuntime(mode, GEOM).run(tr)
        assert len(res) == len(tr)
        for r in res:
            assert r.finish_ns >= r.admit_ns >= r.arrival_ns

    def test_shared_pim_beats_lisa_on_model_fleet(self):
        tr = open_loop_trace(self.tenants(), jobs_per_tenant=5, seed=1)
        p99 = {}
        for mode in Interconnect:
            s = summarize(ServingRuntime(mode, GEOM).run(tr))
            p99[mode] = s["latency_ns"]["p99"]
        assert p99[Interconnect.SHARED_PIM] < p99[Interconnect.LISA]

    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_single_job_session_matches_offline(self, mode):
        # the inference benchmark's bit-for-bit guard, in-suite
        g = ir.materialize(
            partition.partitioned_struct("gemma3-1b", GEOM, phase="decode",
                                         n_layers=2), mode)
        offline = dev_sched.schedule(g, mode, GEOM)
        session = EngineSession(DeviceModel(mode, GEOM))
        session.admit(g)
        session.advance()
        stats = session.stats()
        for f in ("makespan_ns", "op_busy_ns", "move_busy_ns", "stall_ns",
                  "n_ops", "n_moves", "n_rows_moved", "finish_times"):
            assert getattr(stats, f) == getattr(offline, f), f
