"""Sharding-rule unit tests (pure logic — no multi-device requirement)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models import model as model_lib
from repro.sharding import partition


@pytest.fixture(scope="module")
def mesh16():
    # abstract 16x16 mesh over 1 real device is fine for spec computation:
    # we only test the PartitionSpec logic, not placement
    import numpy as np
    devs = np.array(jax.devices() * 256).reshape(16, 16)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _specs_for(arch, mesh):
    cfg = registry.get(arch)
    model = model_lib.build(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = {}
    def visit(path, leaf):
        specs[jax.tree_util.keystr(path)] = partition.param_spec(
            path, leaf.shape, mesh)
        return leaf
    jax.tree_util.tree_map_with_path(visit, shapes)
    return cfg, shapes, specs


@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_all_params_get_valid_specs(arch, mesh16):
    """Every leaf's spec divides its shape on every assigned axis."""
    cfg, shapes, specs = _specs_for(arch, mesh16)
    sizes = {"data": 16, "model": 16}
    flat = {}
    def visit(path, leaf):
        flat[jax.tree_util.keystr(path)] = leaf.shape
        return leaf
    jax.tree_util.tree_map_with_path(visit, shapes)
    for name, spec in specs.items():
        shape = flat[name]
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert shape[dim] % total == 0, (arch, name, shape, spec)


def test_layer_stack_dim_never_sharded(mesh16):
    _, shapes, specs = _specs_for("gemma2-9b", mesh16)
    for name, spec in specs.items():
        if "blocks" in name:
            assert spec[0] is None, (name, spec)


def test_big_matrices_are_2d_sharded(mesh16):
    """FSDP+TP: weight matrices must shard on two axes (1/256 per chip)."""
    _, shapes, specs = _specs_for("glm4-9b", mesh16)
    mlp_specs = [s for n, s in specs.items()
                 if "wi_gate" in n or ("mlp" in n and "wo" in n)]
    assert mlp_specs
    for s in mlp_specs:
        named = [e for e in s if e is not None]
        assert len(named) == 2, s


def test_moe_expert_sharding_ep_vs_tp(mesh16):
    """llama4 (128 experts) -> EP on the expert dim; qwen2 (60) -> TP."""
    _, _, specs4 = _specs_for("llama4-maverick-400b-a17b", mesh16)
    ep = [s for n, s in specs4 if False] if False else None
    expert = {n: s for n, s in specs4.items() if "moe" in n and
              "wi_gate" in n and "shared" not in n}
    assert expert
    for n, s in expert.items():
        assert s[1] == "model", (n, s)     # (L, E, d, f): E -> model

    _, _, specs2 = _specs_for("qwen2-moe-a2.7b", mesh16)
    expert2 = {n: s for n, s in specs2.items() if "moe" in n and
               "wi_gate" in n and "shared" not in n}
    for n, s in expert2.items():
        assert s[1] is None and s[3] == "model", (n, s)  # f -> model


def test_batch_spec_multi_pod():
    import numpy as np
    devs = np.array(jax.devices() * 512).reshape(2, 16, 16)
    mesh = jax.sharding.Mesh(devs, ("pod", "data", "model"))
    assert partition.batch_spec(mesh, 256) == P(("pod", "data"))
    # unshardable batch (e.g. long_500k B=1) -> replicated
    assert partition.batch_spec(mesh, 1) == P()


def test_cache_seq_sharding_fallback(mesh16):
    """B=1 decode: KV sequence dim takes the data axis instead of batch."""
    path = (jax.tree_util.DictKey("k"),)
    spec = partition.cache_spec(path, (54, 1, 524288, 32, 80), mesh16, 1)
    assert spec[1] is None
    assert spec[2] in ("data", ("data",))   # P normalizes singleton tuples
    assert spec[3] == "model"
