"""Serving runtime: traces, bank leasing, and the end-to-end driver."""

import pytest

from repro.core.engine import RefreshSpec
from repro.core.pluto import Interconnect
from repro.device import DeviceGeometry
from repro.device.partition import lease_pe_map, place_on_banks
from repro.core import taskgraph
from repro.runtime import (ADMISSION_POLICIES, BankAllocator,
                           ClosedLoopSource, ServingRuntime, TenantSpec,
                           open_loop_trace, summarize)

GEOM = DeviceGeometry(channels=1, banks_per_channel=4)


def tenants(rate=2000.0):
    return [
        TenantSpec.make("mm", "mm", n=16, banks=2, rate_jps=rate),
        TenantSpec.make("bfs", "bfs", n_nodes=30, priority=2,
                        rate_jps=rate),
        TenantSpec.make("ntt", "ntt", n=16, rate_jps=rate),
    ]


class TestTrace:
    def test_deterministic_in_seed(self):
        a = open_loop_trace(tenants(), jobs_per_tenant=5, seed=3)
        b = open_loop_trace(tenants(), jobs_per_tenant=5, seed=3)
        c = open_loop_trace(tenants(), jobs_per_tenant=5, seed=4)
        assert [r.sort_key for r in a] == [r.sort_key for r in b]
        assert [r.sort_key for r in a] != [r.sort_key for r in c]

    def test_sorted_and_counted(self):
        tr = open_loop_trace(tenants(), jobs_per_tenant=7, seed=0)
        assert len(tr) == 21
        arrivals = [r.arrival_ns for r in tr]
        assert arrivals == sorted(arrivals)
        for name in ("mm", "bfs", "ntt"):
            assert sum(r.tenant.name == name for r in tr) == 7

    def test_load_scales_rates(self):
        slow = open_loop_trace(tenants(), jobs_per_tenant=20, seed=0,
                               load=0.5)
        fast = open_loop_trace(tenants(), jobs_per_tenant=20, seed=0,
                               load=2.0)
        assert fast[-1].arrival_ns < slow[-1].arrival_ns

    def test_horizon_bound(self):
        tr = open_loop_trace(tenants(), horizon_ns=1e6, seed=0)
        assert all(r.arrival_ns < 1e6 for r in tr)

    def test_exactly_one_bound_required(self):
        with pytest.raises(ValueError):
            open_loop_trace(tenants(), seed=0)
        with pytest.raises(ValueError):
            open_loop_trace(tenants(), jobs_per_tenant=2, horizon_ns=1.0)

    def test_zero_rate_under_job_bounding_raises(self):
        # regression: a rate<=0 tenant used to silently emit an empty
        # stream, breaking the "every load level completes the same job
        # population" invariant of cross-load comparisons
        zero = [TenantSpec.make("idle", "mm", n=16, rate_jps=0.0)]
        with pytest.raises(ValueError, match="idle"):
            open_loop_trace(zero, jobs_per_tenant=3, seed=0)
        with pytest.raises(ValueError, match="load"):
            open_loop_trace(tenants(), jobs_per_tenant=3, seed=0, load=0.0)
        # a horizon-bounded window legitimately contains no arrivals
        assert open_loop_trace(zero, horizon_ns=1e6, seed=0) == []

    def test_closed_loop_budget_and_determinism(self):
        ts = [TenantSpec.make("mm", "mm", n=16, concurrency=2,
                              think_ns=50.0)]
        src = ClosedLoopSource(ts, jobs_per_tenant=5, seed=1)
        first = src.initial()
        assert len(first) == 2 and all(r.arrival_ns == 0.0 for r in first)
        seen = list(first)
        while True:
            nxt = src.on_complete(seen[-1], seen[-1].arrival_ns + 100.0)
            if nxt is None:
                break
            seen.append(nxt)
        assert len(seen) == 5
        assert [r.seq for r in seen] == list(range(5))


class TestLeaseMap:
    def test_identity_on_full_contiguous_lease(self):
        m = lease_pe_map(GEOM, range(GEOM.n_banks))
        assert m == list(range(GEOM.total_pes))

    def test_maps_into_leased_banks_only(self):
        banks = (1, 3)
        m = lease_pe_map(GEOM, banks)
        ppb = GEOM.pes_per_bank
        assert {p // ppb for p in m} == set(banks)
        assert len(set(m)) == len(m) == len(banks) * ppb

    def test_rejects_bad_leases(self):
        with pytest.raises(ValueError):
            lease_pe_map(GEOM, [])
        with pytest.raises(ValueError):
            lease_pe_map(GEOM, [0, 0])
        with pytest.raises(ValueError):
            lease_pe_map(GEOM, [99])

    def test_place_on_banks_confines_graph(self):
        g = taskgraph.structural("mm", n_pes=2 * GEOM.pes_per_bank, n=12)
        placed = place_on_banks(g, GEOM, (2, 3))
        ppb = GEOM.pes_per_bank
        pes = set(placed.pe[placed.pe >= 0].tolist()) \
            | set(placed.src[placed.src >= 0].tolist()) \
            | set(placed.dst_flat.tolist())
        assert {p // ppb for p in pes} <= {2, 3}


class TestAllocator:
    def test_grant_release_roundtrip(self):
        al = BankAllocator(GEOM, "fifo")
        leases = al.request(3, payload="a")
        assert len(leases) == 1 and leases[0].banks == (0, 1, 2)
        assert al.n_free == 1
        assert al.request(2, payload="b") == []       # queued
        granted = al.release(leases[0])
        assert [ls.payload for ls in granted] == ["b"]
        assert al.n_free == 2

    def test_contiguous_preference(self):
        al = BankAllocator(GEOM, "fifo")
        a = al.request(1)[0]
        b = al.request(1)[0]
        assert (a.banks, b.banks) == ((0,), (1,))
        al.release(a)                                 # free: {0, 2, 3}
        c = al.request(2)[0]
        assert c.banks == (2, 3)                      # contiguous beats low

    def test_fifo_head_of_line_blocks(self):
        al = BankAllocator(GEOM, "fifo")
        big = al.request(4)[0]
        assert al.request(4, payload="jumbo") == []
        assert al.request(1, payload="tiny") == []    # behind jumbo
        granted = al.release(big)
        assert [ls.payload for ls in granted] == ["jumbo"]

    def test_sjf_reorders_by_cost(self):
        al = BankAllocator(GEOM, "sjf")
        lease = al.request(4, cost=1.0)[0]
        al.request(2, cost=50.0, payload="slow")
        al.request(2, cost=5.0, payload="quick")
        granted = al.release(lease)
        assert [ls.payload for ls in granted] == ["quick", "slow"]

    def test_priority_order_then_fifo(self):
        al = BankAllocator(GEOM, "priority")
        lease = al.request(4, priority=0)[0]
        al.request(1, priority=0, payload="low")
        al.request(1, priority=5, payload="hi")
        al.request(1, priority=5, payload="hi2")
        granted = al.release(lease)
        assert [ls.payload for ls in granted] == ["hi", "hi2", "low"]

    def test_rejects_oversized_and_double_release(self):
        al = BankAllocator(GEOM, "fifo")
        with pytest.raises(ValueError):
            al.request(5)
        lease = al.request(1)[0]
        al.release(lease)
        with pytest.raises(ValueError):
            al.release(lease)
        with pytest.raises(ValueError):
            BankAllocator(GEOM, "lifo")

    def test_stale_lease_cannot_free_released_banks(self):
        # regression: release() used to only cross-check the freed banks
        # against the *free* set, so releasing a stale lease whose banks
        # had been re-leased silently freed another tenant's banks mid-job
        al = BankAllocator(GEOM, "fifo")
        stale = al.request(2, payload="a")[0]
        al.release(stale)
        fresh = al.request(2, payload="b")[0]
        assert fresh.banks == stale.banks      # same banks, new tenant
        with pytest.raises(ValueError, match="already-released"):
            al.release(stale)
        assert al.n_free == GEOM.n_banks - 2   # b's banks stayed leased
        assert al.n_leased == 1
        al.release(fresh)
        assert al.n_free == GEOM.n_banks and al.n_leased == 0

    def test_foreign_and_tampered_leases_rejected(self):
        from repro.runtime.allocator import Lease

        al = BankAllocator(GEOM, "fifo")
        lease = al.request(2)[0]
        with pytest.raises(ValueError, match="unknown"):
            al.release(Lease(ticket=999, banks=(0, 1)))
        with pytest.raises(ValueError, match="granted banks"):
            al.release(Lease(ticket=lease.ticket, banks=(2, 3)))
        assert al.n_leased == 1                # still intact
        al.release(lease)


class TestServingRuntime:
    def trace(self, n=6, seed=0):
        return open_loop_trace(tenants(), jobs_per_tenant=n, seed=seed)

    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_serves_every_job_causally(self, mode):
        tr = self.trace()
        rt = ServingRuntime(mode, GEOM)
        res = rt.run(tr)
        assert len(res) == len(tr)
        for r in res:
            assert r.finish_ns >= r.admit_ns >= r.arrival_ns
            assert set(r.banks) <= set(range(GEOM.n_banks))

    def test_deterministic_replay(self):
        a = ServingRuntime(Interconnect.SHARED_PIM, GEOM).run(self.trace())
        b = ServingRuntime(Interconnect.SHARED_PIM, GEOM).run(self.trace())
        assert a == b

    @pytest.mark.parametrize("policy", ADMISSION_POLICIES)
    def test_policies_serve_identical_job_sets(self, policy):
        tr = self.trace()
        res = ServingRuntime(Interconnect.SHARED_PIM, GEOM,
                             admission=policy).run(tr)
        assert sorted((r.tenant, r.seq) for r in res) \
            == sorted((r.tenant.name, r.seq) for r in tr)

    def test_shared_pim_latency_beats_lisa(self):
        tr = self.trace(n=8)
        lat = {}
        for mode in Interconnect:
            s = summarize(ServingRuntime(mode, GEOM).run(tr))
            lat[mode] = s["latency_ns"]["p99"]
        assert lat[Interconnect.SHARED_PIM] < lat[Interconnect.LISA]

    def test_refresh_only_adds_latency(self):
        tr = self.trace(n=4)
        base = summarize(ServingRuntime(Interconnect.SHARED_PIM, GEOM)
                         .run(tr))
        spec = RefreshSpec(interval_ns=3000.0, duration_ns=500.0)
        rt = ServingRuntime(Interconnect.SHARED_PIM, GEOM, refresh=spec)
        with_r = summarize(rt.run(tr))
        assert rt.session.stats().refresh_ns > 0.0
        assert with_r["mean_latency_ns"] >= base["mean_latency_ns"]

    def test_priority_admission_helps_urgent_tenant_under_load(self):
        # saturate the device so the queue is never empty, then compare the
        # urgent tenant's p99 under fifo vs priority admission
        tr = open_loop_trace(tenants(rate=50000.0), jobs_per_tenant=10,
                             seed=2)
        by = {}
        for policy in ("fifo", "priority"):
            res = ServingRuntime(Interconnect.SHARED_PIM, GEOM,
                                 admission=policy).run(tr)
            by[policy] = summarize(res)["per_tenant"]["bfs"]["p99_ns"]
        assert by["priority"] < by["fifo"]

    def test_closed_loop_self_limits(self):
        ts = [TenantSpec.make("mm", "mm", n=16, banks=1, concurrency=2)]
        src = ClosedLoopSource(ts, jobs_per_tenant=6, seed=0)
        rt = ServingRuntime(Interconnect.SHARED_PIM, GEOM)
        res = rt.run((), closed=src)
        assert len(res) == 6
        # never more than `concurrency` jobs overlap in service
        events = [(r.admit_ns, 1) for r in res] + \
                 [(r.finish_ns, -1) for r in res]
        live = peak = 0
        for _, d in sorted(events):
            live += d
            peak = max(peak, live)
        assert peak <= 2

    def test_oversized_tenant_rejected(self):
        bad = [TenantSpec.make("big", "mm", n=16, banks=GEOM.n_banks + 1)]
        tr = open_loop_trace(bad, jobs_per_tenant=1, seed=0)
        with pytest.raises(ValueError, match="banks"):
            ServingRuntime(Interconnect.LISA, GEOM).run(tr)

    def test_summary_shape(self):
        s = summarize([])
        assert s["n_jobs"] == 0 and s["throughput_jps"] == 0.0
        assert s["makespan_ns"] == s["t_start_ns"] == s["t_end_ns"] == 0.0
        res = ServingRuntime(Interconnect.LISA, GEOM).run(self.trace(n=3))
        s = summarize(res)
        assert s["n_jobs"] == len(res)
        assert set(s["latency_ns"]) == {"p50", "p95", "p99"}
        assert s["latency_ns"]["p50"] <= s["latency_ns"]["p99"]

    def test_summary_makespan_is_the_span(self):
        # regression: makespan_ns used to report the absolute last finish,
        # not the first-arrival -> last-finish span the throughput divides
        # by; on a batch starting at t>0 the two differ
        from repro.runtime.serve import JobResult

        res = [JobResult("t", "mm", 0, 1000.0, 1100.0, 2000.0, (0,), 5),
               JobResult("t", "mm", 1, 1500.0, 1600.0, 3500.0, (0,), 5)]
        s = summarize(res)
        assert s["makespan_ns"] == 2500.0      # 3500 - 1000, not 3500
        assert s["t_start_ns"] == 1000.0 and s["t_end_ns"] == 3500.0
        assert s["throughput_jps"] == pytest.approx(2 / 2500.0 * 1e9)
