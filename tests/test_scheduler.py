"""Scheduler invariants: dependency order, resource exclusivity, STALL/NOP."""

import pytest

from _hypothesis_compat import hypothesis, st  # noqa: F401

from repro.core import scheduler as sch
from repro.core import taskgraph
from repro.core.pluto import Interconnect
from repro.core.scheduler import Task


def _chain(n=4, dur=100.0):
    return [Task(i, "op", deps=(i - 1,) if i else (), pe=i % 4, duration=dur)
            for i in range(n)]


class TestBasics:
    def test_serial_chain_makespan(self):
        r = sch.schedule(_chain(4), Interconnect.LISA)
        assert r.makespan_ns == pytest.approx(400.0)

    def test_parallel_ops_overlap(self):
        tasks = [Task(i, "op", pe=i, duration=100.0) for i in range(4)]
        r = sch.schedule(tasks, Interconnect.LISA)
        assert r.makespan_ns == pytest.approx(100.0)

    def test_same_pe_serializes(self):
        tasks = [Task(i, "op", pe=0, duration=100.0) for i in range(4)]
        r = sch.schedule(tasks, Interconnect.LISA)
        assert r.makespan_ns == pytest.approx(400.0)

    def test_cycle_detection(self):
        tasks = [Task(0, "op", deps=(1,), pe=0, duration=1.0),
                 Task(1, "op", deps=(0,), pe=0, duration=1.0)]
        with pytest.raises(ValueError):
            sch.schedule(tasks, Interconnect.LISA)


class TestConcurrencySemantics:
    """The paper's core claim, as scheduler behaviour."""

    def test_lisa_move_stalls_compute_in_span(self):
        # op on PE1 is independent of the move 0->2, but sits in its span
        tasks = [Task(0, "move", src=0, dst=2, rows=8),
                 Task(1, "op", pe=1, duration=100.0)]
        r = sch.schedule(tasks, Interconnect.LISA)
        # move duration: 8 rows x lisa(d=2); op must wait for it
        assert r.makespan_ns > 8 * 423.5
        assert r.stall_ns > 0

    def test_sharedpim_move_concurrent_with_compute(self):
        tasks = [Task(0, "move", src=0, dst=2, rows=8),
                 Task(1, "op", pe=1, duration=100.0)]
        r = sch.schedule(tasks, Interconnect.SHARED_PIM)
        # op runs during the bus transfer: makespan == move duration
        assert r.makespan_ns == pytest.approx(8 * 52.75)
        assert r.stall_ns == 0

    def test_bus_serializes_sharedpim_moves(self):
        tasks = [Task(0, "move", src=0, dst=2, rows=1),
                 Task(1, "move", src=4, dst=6, rows=1)]
        r = sch.schedule(tasks, Interconnect.SHARED_PIM)
        assert r.makespan_ns == pytest.approx(2 * 52.75)

    def test_sharedpim_distance_free_lisa_not(self):
        near = [Task(0, "move", src=0, dst=1, rows=1)]
        far = [Task(0, "move", src=0, dst=9, rows=1)]
        for mk in (near, far):
            pass
        l_near = sch.schedule(near, Interconnect.LISA).makespan_ns
        l_far = sch.schedule(far, Interconnect.LISA).makespan_ns
        s_near = sch.schedule(near, Interconnect.SHARED_PIM).makespan_ns
        s_far = sch.schedule(far, Interconnect.SHARED_PIM).makespan_ns
        assert l_far > l_near
        assert s_far == s_near

    def test_broadcast_single_transaction(self):
        tasks = [Task(0, "move", src=0, dst=(1, 2, 3, 4), rows=1)]
        r = sch.schedule(tasks, Interconnect.SHARED_PIM)
        assert r.makespan_ns == pytest.approx(64.75)

    def test_shared_row_tokens_limit_concurrency(self):
        # two moves out of the same source serialize on its tx shared row
        tasks = [Task(0, "move", src=0, dst=2, rows=1),
                 Task(1, "move", src=0, dst=5, rows=1)]
        r = sch.schedule(tasks, Interconnect.SHARED_PIM)
        assert r.makespan_ns == pytest.approx(2 * 52.75)


@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 25))
    tasks = []
    for i in range(n):
        deps = tuple(d for d in range(i)
                     if draw(st.booleans()) and d >= i - 3)
        if draw(st.booleans()):
            tasks.append(Task(i, "op", deps=deps, pe=draw(st.integers(0, 15)),
                              duration=draw(st.floats(1.0, 1e4))))
        else:
            src = draw(st.integers(0, 15))
            dst = draw(st.integers(0, 15).filter(lambda d: d != src))
            tasks.append(Task(i, "move", deps=deps, src=src, dst=dst,
                              rows=draw(st.integers(1, 16))))
    return tasks


class TestProperties:
    @hypothesis.given(random_dag(), st.sampled_from(list(Interconnect)))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_dependencies_respected(self, tasks, mode):
        r = sch.schedule(tasks, mode)
        by_uid = {t.uid: t for t in tasks}
        for uid, t in by_uid.items():
            for d in t.deps:
                assert r.finish_times[d] <= r.finish_times[uid] + 1e-9

    @hypothesis.given(random_dag())
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_sharedpim_never_slower_than_lisa(self, tasks):
        """The paper's claim holds for EVERY dataflow: SP makespan <= LISA."""
        lisa = sch.schedule(tasks, Interconnect.LISA).makespan_ns
        sp = sch.schedule(tasks, Interconnect.SHARED_PIM).makespan_ns
        assert sp <= lisa + 1e-6

    @hypothesis.given(random_dag(), st.sampled_from(list(Interconnect)))
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_all_tasks_complete(self, tasks, mode):
        r = sch.schedule(tasks, mode)
        assert len(r.finish_times) == len(tasks)
        assert r.n_ops + r.n_moves == len(tasks)


class TestFig8Applications:
    """Application-level reproduction (paper Fig 8) at paper problem sizes."""

    # (app, kwargs, paper improvement, tolerance in percentage points)
    CASES = [
        ("mm", dict(n=200), 0.40, 0.04),
        ("pmm", dict(n=300), 0.44, 0.04),
        ("ntt", dict(n=512), 0.31, 0.03),
        ("bfs", dict(n_nodes=1000), 0.29, 0.03),
        ("dfs", dict(n_nodes=1000), 0.29, 0.03),
    ]

    @pytest.mark.parametrize("app,kw,target,tol", CASES)
    def test_app_improvement_matches_paper(self, app, kw, target, tol):
        res = {m: sch.schedule(taskgraph.build(app, m, **kw), m)
               for m in Interconnect}
        imp = 1.0 - (res[Interconnect.SHARED_PIM].makespan_ns
                     / res[Interconnect.LISA].makespan_ns)
        assert imp == pytest.approx(target, abs=tol), \
            f"{app}: got {imp:.3f}, paper claims {target}"

    def test_transfer_energy_savings(self):
        """Paper: ~18% average energy savings in data transfers."""
        savings = []
        for app, kw, *_ in self.CASES:
            res = {m: sch.schedule(taskgraph.build(app, m, **kw), m)
                   for m in Interconnect}
            savings.append(
                1.0 - res[Interconnect.SHARED_PIM].transfer_energy_j
                / res[Interconnect.LISA].transfer_energy_j)
        avg = sum(savings) / len(savings)
        assert avg == pytest.approx(0.18, abs=0.02)

    def test_bfs_equals_dfs(self):
        """Paper Sec IV-D: identical worst-case behaviour."""
        for m in Interconnect:
            b = sch.schedule(taskgraph.build("bfs", m, n_nodes=100), m)
            d = sch.schedule(taskgraph.build("dfs", m, n_nodes=100), m)
            assert b.makespan_ns == d.makespan_ns
