"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model as model_lib

ARCHS = list(registry.ARCHS)


def _batch(cfg, B=2, T=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T), dtype=np.int32))}
    if cfg.n_media_tokens:
        batch["media"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_media_tokens, cfg.media_embed_dim))
            .astype(np.float32))
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = registry.get(arch).reduced()
        m = model_lib.build(cfg)
        params = m.init(jax.random.key(0))
        out[arch] = (cfg, m, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(built, arch):
    cfg, m, params = built[arch]
    batch = _batch(cfg)
    logits = jax.jit(m.forward)(params, batch)
    B, T = batch["tokens"].shape
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), \
        f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_and_grads_finite(built, arch):
    cfg, m, params = built[arch]
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(m.train_loss))(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all()), \
            f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(built, arch):
    cfg, m, params = built[arch]
    B, S = 2, 32
    cache = m.init_cache(B, S)
    # simulate a cache mid-sequence
    cache["pos"] = jnp.asarray(7, jnp.int32)
    tok = jnp.ones((B, 1), jnp.int32)
    media = (jnp.zeros((B, cfg.n_media_tokens, cfg.media_embed_dim),
                       jnp.float32) if cfg.n_media_tokens else None)
    logits, cache2 = jax.jit(m.decode_step)(params, cache, tok, media)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["pos"]) == 8


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = registry.get("granite-3-2b").reduced()
    m = model_lib.build(cfg)
    params = m.init(jax.random.key(1))
    rng = np.random.default_rng(3)
    T = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T), np.int32))
    full = m.forward(params, {"tokens": toks})
    cache = m.init_cache(1, T)
    step = jax.jit(m.decode_step)
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t:t + 1], None)
        np.testing.assert_allclose(
            np.asarray(logits[0, 0]), np.asarray(full[0, t]),
            rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    """Recurrent decode == parallel scan for the mamba family."""
    cfg = registry.get("falcon-mamba-7b").reduced()
    m = model_lib.build(cfg)
    params = m.init(jax.random.key(2))
    rng = np.random.default_rng(4)
    T = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T), np.int32))
    full = m.forward(params, {"tokens": toks})
    cache = m.init_cache(1, T)
    step = jax.jit(m.decode_step)
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t:t + 1], None)
        np.testing.assert_allclose(
            np.asarray(logits[0, 0]), np.asarray(full[0, t]),
            rtol=2e-2, atol=2e-2)


def test_local_global_flags_gemma():
    cfg = registry.get("gemma2-9b")
    m = model_lib.build(cfg)
    flags = np.asarray(m._layer_is_global())
    assert flags.shape == (42,)
    assert flags[1::2].all() and not flags[0::2].any()


def test_full_configs_match_spec():
    """Assigned-architecture hyperparameters are exactly as listed."""
    spec = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151_936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202_048),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262_144),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49_155),
        "gemma2-9b": (42, 3584, 16, 8, 14_336, 256_000),
        "glm4-9b": (40, 4096, 32, 2, 13_696, 151_552),
        "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65_024),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14_336, 128_256),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = registry.get(arch)
        ff_actual = cfg.moe_d_ff if cfg.family == "moe" else cfg.d_ff
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               ff_actual, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), f"{arch}: {got}"
    assert registry.get("zamba2-2.7b").ssm_state == 64
    assert registry.get("falcon-mamba-7b").ssm_state == 16
