"""Bit-for-bit equivalence of the resource-token engine with the goldens.

``tests/golden_schedules.json`` (captured by ``tests/capture_goldens.py``
from the pre-refactor schedulers) pins every observable of 114 schedules:
makespan, busy/stall breakdowns, counts, energy, route/bus breakdowns, and
a SHA-256 digest of the per-task finish times.  The refactored engine must
reproduce all of them exactly — no tolerance.

A second layer cross-checks the engine against the *live* legacy
implementations (:mod:`repro.core.reference`, :mod:`repro.device.reference`)
on randomized graphs, covering shapes the golden grid does not.
"""

import json
from pathlib import Path

import pytest

from _hypothesis_compat import hypothesis, st  # noqa: F401

from capture_goldens import (APP_KW, GEOMETRIES, SYNTH, core_record,
                             device_record)
from repro.core import reference as core_ref
from repro.core import scheduler as core_sched
from repro.core import taskgraph
from repro.core.pluto import Interconnect
from repro.core.scheduler import Task
from repro.device import DeviceGeometry, build_partitioned
from repro.device import reference as dev_ref
from repro.device import scheduler as dev_sched

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_schedules.json").read_text())

BIG = DeviceGeometry(**GEOMETRIES["2ch_4banks_2groups"])


def _device_cases():
    for gname in GEOMETRIES:
        geom = DeviceGeometry(**GEOMETRIES[gname])
        for app in APP_KW:
            for scaling in ("strong", "weak"):
                policies = (("locality_first", "round_robin",
                             "bandwidth_balanced")
                            if scaling == "strong" and geom.n_banks > 1
                            else ("locality_first",))
                for policy in policies:
                    yield gname, app, scaling, policy


class TestGoldenCore:
    @pytest.mark.parametrize("app", sorted(APP_KW))
    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_core_schedule_bit_for_bit(self, app, mode):
        tasks = taskgraph.build(app, mode, **APP_KW[app])
        rec = core_record(core_sched.schedule(tasks, mode))
        assert rec == GOLDEN["core"][f"{app}/{mode.value}"]


class TestGoldenDevice:
    @pytest.mark.parametrize("gname,app,scaling,policy",
                             sorted(set(_device_cases())))
    def test_device_schedule_bit_for_bit(self, gname, app, scaling, policy):
        geom = DeviceGeometry(**GEOMETRIES[gname])
        for mode in Interconnect:
            tasks = build_partitioned(app, mode, geom, policy=policy,
                                      scaling=scaling, **APP_KW[app])
            rec = device_record(dev_sched.schedule(tasks, mode, geom))
            key = f"{app}/{mode.value}/{gname}/{scaling}/{policy}"
            assert rec == GOLDEN["device"][key], key

    @pytest.mark.parametrize("name", sorted(SYNTH))
    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_synthetic_graphs_bit_for_bit(self, name, mode):
        rec = device_record(dev_sched.schedule(SYNTH[name], mode, BIG))
        assert rec == GOLDEN["synth"][f"{name}/{mode.value}"]


CORE_FIELDS = ("makespan_ns", "op_busy_ns", "move_busy_ns", "stall_ns",
               "n_ops", "n_moves", "n_rows_moved", "finish_times")
DEVICE_FIELDS = CORE_FIELDS + ("transfer_energy_j", "n_cross_moves",
                               "rows_by_route", "bus_busy_ns")


def assert_same(a, b, fields):
    for f in fields:
        assert getattr(a, f) == getattr(b, f), f


@st.composite
def random_device_dag(draw):
    n = draw(st.integers(2, 30))
    total = BIG.total_pes
    tasks = []
    for i in range(n):
        deps = tuple(d for d in range(max(0, i - 4), i)
                     if draw(st.booleans()))
        if draw(st.booleans()):
            tasks.append(Task(i, "op", deps=deps,
                              pe=draw(st.integers(0, total - 1)),
                              duration=draw(st.floats(1.0, 1e4))))
        else:
            src = draw(st.integers(0, total - 1))
            if draw(st.booleans()):
                dst = draw(st.integers(0, total - 1)
                           .filter(lambda d: d != src))
            else:
                dst = tuple(draw(
                    st.lists(st.integers(0, total - 1).filter(
                        lambda d: d != src),
                        min_size=2, max_size=5, unique=True)))
            tasks.append(Task(i, "move", deps=deps, src=src, dst=dst,
                              rows=draw(st.integers(1, 8))))
    return tasks


class TestLiveReferenceDifferential:
    """Engine vs the preserved legacy implementations on random graphs."""

    @hypothesis.given(random_device_dag(), st.sampled_from(list(Interconnect)))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_core_engine_matches_reference(self, tasks, mode):
        assert_same(core_sched.schedule(tasks, mode),
                    core_ref.schedule(tasks, mode), CORE_FIELDS)

    @hypothesis.given(random_device_dag(), st.sampled_from(list(Interconnect)))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_device_engine_matches_reference(self, tasks, mode):
        assert_same(dev_sched.schedule(tasks, mode, BIG),
                    dev_ref.schedule(tasks, mode, BIG), DEVICE_FIELDS)


class TestDeterminism:
    """Satellite: total (priority, uid) ordering — no tie-break accidents."""

    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_repeat_runs_identical(self, mode):
        tasks = taskgraph.build("mm", mode, n=30)
        a = core_sched.schedule(tasks, mode)
        b = core_sched.schedule(tasks, mode)
        assert a.finish_times == b.finish_times
        assert a.makespan_ns == b.makespan_ns

    @pytest.mark.parametrize("mode", list(Interconnect))
    def test_input_order_irrelevant(self, mode):
        """Reversing task insertion order must not change the schedule."""
        tasks = taskgraph.build("ntt", mode, n=64)
        fwd = core_sched.schedule(tasks, mode)
        rev = core_sched.schedule(list(reversed(tasks)), mode)
        assert fwd.finish_times == rev.finish_times

    def test_equal_priority_ties_break_by_uid(self):
        # two identical ready ops contending for one PE: the lower uid must
        # consistently schedule first
        tasks = [Task(5, "op", pe=0, duration=10.0),
                 Task(2, "op", pe=0, duration=10.0)]
        r = core_sched.schedule(tasks, Interconnect.LISA)
        assert r.finish_times[2] == 10.0
        assert r.finish_times[5] == 20.0
        r2 = core_sched.schedule(list(reversed(tasks)), Interconnect.LISA)
        assert r2.finish_times == r.finish_times
