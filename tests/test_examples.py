"""Smoke-run every ``examples/`` script so frontend API churn can't
silently break them (none of them was executed by the suite before)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
EXAMPLES = REPO / "examples"

#: every example with its expected stdout fingerprints (cheap sanity that
#: the script not only exited 0 but did its job)
CASES = {
    "quickstart.py": ("Table II", "improvement", "Device scale"),
    "pim_pipeline.py": ("NTT", "bit-exact"),
    "serve_batch.py": ("glm4-9b", "falcon-mamba-7b"),
    "trace_viewer.py": ("moe-decode", ".trace.json", "ui.perfetto.dev"),
}


@pytest.mark.slow
@pytest.mark.parametrize("script", sorted(CASES))
def test_example_runs(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / script)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert proc.returncode == 0, \
        f"{script} failed:\n{proc.stdout}\n{proc.stderr}"
    for token in CASES[script]:
        assert token in proc.stdout, \
            f"{script} output missing {token!r}:\n{proc.stdout}"


def test_every_example_is_covered():
    """A new example script must be added to CASES (or consciously skipped)."""
    scripts = {p.name for p in EXAMPLES.glob("*.py")}
    known_uncovered = {"train_lm.py"}   # full training loop: covered by
    #   tests/test_train_infra.py at reduced scale; too slow as a subprocess
    assert scripts - known_uncovered == set(CASES)
