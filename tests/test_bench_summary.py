"""benchmarks/run.py artifact consolidation: headline metrics + guards."""

import json

from benchmarks.run import summarize_bench_artifacts


def write(tmp_path, name, data):
    (tmp_path / name).write_text(json.dumps(data))


def test_collects_headlines_and_guard_verdicts(tmp_path):
    write(tmp_path, "BENCH_sweep.json",
          {"speedup": 5.5, "bit_for_bit_identical": True, "failures": []})
    write(tmp_path, "BENCH_device.json",
          {"monotone_ok": True, "sweep": [{"improvement": 0.4},
                                          {"improvement": 0.7}]})
    write(tmp_path, "BENCH_serving.json",
          {"guard_ok": True, "failures": [], "session_matches_offline": True,
           "sustained_load": {"shared_pim": {"fifo": 1.5, "sjf": 1.2}}})
    write(tmp_path, "BENCH_inference.json",
          {"guard_ok": True, "failures": [], "session_matches_offline": True,
           "sustained_load": {"shared_pim": {"fifo": 0.9}}})
    rows = {r["name"]: r for r in summarize_bench_artifacts(tmp_path)}
    assert rows["BENCH_sweep"]["value"] == 5.5
    assert rows["BENCH_device"]["value"] == 0.7
    assert rows["BENCH_serving"]["value"] == 1.5
    assert rows["BENCH_inference"]["value"] == 0.9
    assert all(r["guard"] == "PASS" for r in rows.values())


def test_failed_guard_is_flagged(tmp_path):
    write(tmp_path, "BENCH_sweep.json",
          {"speedup": 9.9, "bit_for_bit_identical": True,
           "failures": ["speedup below bar"]})
    write(tmp_path, "BENCH_device.json", {"monotone_ok": False, "sweep": []})
    rows = {r["name"]: r for r in summarize_bench_artifacts(tmp_path)}
    assert rows["BENCH_sweep"]["guard"] == "FAIL"
    assert rows["BENCH_device"]["guard"] == "FAIL"


def test_unknown_and_unreadable_artifacts(tmp_path):
    write(tmp_path, "BENCH_custom.json", {"whatever": 1})
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    rows = {r["name"]: r for r in summarize_bench_artifacts(tmp_path)}
    assert rows["BENCH_custom"]["guard"] == "NONE"
    assert rows["BENCH_broken"]["guard"] == "UNREADABLE"


def test_repo_artifacts_are_green():
    """The committed BENCH_*.json must never record a failed guard."""
    for row in summarize_bench_artifacts():
        assert row["guard"] in ("PASS", "NONE"), row
