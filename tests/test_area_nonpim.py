"""Table III (area) and Fig 9 (non-PIM IPC) reproduction tests."""

import pytest

from repro.core import area, nonpim


class TestTable3:
    def test_totals(self):
        assert area.total(0) == pytest.approx(70.24)
        # paper prints 82.00; its own column sums to 82.01 (rounding in the
        # published table) — we assert the computed sum
        assert area.total(1) == pytest.approx(82.01)
        assert area.total(2) == pytest.approx(87.87)

    def test_overhead_claim(self):
        """Paper claim: +7.16% vs pLUTo (7.15% from the exact column sums)."""
        assert area.sharedpim_overhead_pct() == pytest.approx(7.16, abs=0.02)

    def test_additions_are_sharedpim_only(self):
        for comp in ("GWL driver", "BK-bus lines", "BK-SAs",
                     "Shared-PIM Row decoder"):
            base, pluto_, sp = area.TABLE_III[comp]
            assert base is None and pluto_ is None and sp is not None


class TestFig9:
    def test_memcpy_is_unity_baseline(self):
        for app, row in nonpim.fig9_table().items():
            assert row["memcpy"] == pytest.approx(1.0)

    def test_no_regressions_anywhere(self):
        """Paper Sec IV-E: Shared-PIM never degrades non-PIM performance."""
        for app, row in nonpim.fig9_table().items():
            assert row["shared_pim"] >= row["lisa"] >= row["memcpy"]

    def test_bootup_benefits_most(self):
        """Paper: 'Shared-PIM shows the highest benefit in Bootup'."""
        t = nonpim.fig9_table()
        best = max(t, key=lambda a: t[a]["shared_pim"])
        assert best == "bootup"

    def test_table4_latencies(self):
        assert nonpim.T_MEMCPY == pytest.approx(1366.25)
        assert nonpim.T_LISA == pytest.approx(260.5)
        assert nonpim.T_SHAREDPIM == pytest.approx(158.25)
