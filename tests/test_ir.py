"""TaskGraph IR: construction, round-trip, validation, derived structure."""

import numpy as np
import pytest

from repro.core import taskgraph
from repro.core.ir import GraphBuilder, from_tasks, materialize, to_tasks
from repro.core.pluto import Interconnect
from repro.core.scheduler import Task


def diamond_tasks():
    return [
        Task(0, "op", pe=0, duration=10.0),
        Task(1, "move", deps=(0,), src=0, dst=2, rows=4),
        Task(2, "move", deps=(0,), src=0, dst=(3, 4), rows=2),
        Task(3, "op", deps=(1, 2), pe=2, duration=5.0, tag="join"),
    ]


class TestRoundTrip:
    def test_from_to_tasks_identity(self):
        tasks = diamond_tasks()
        assert to_tasks(from_tasks(tasks)) == tasks

    def test_arbitrary_uids_preserved(self):
        tasks = [Task(42, "op", pe=1, duration=1.0),
                 Task(7, "op", deps=(42,), pe=2, duration=2.0)]
        g = from_tasks(tasks)
        assert g.uids.tolist() == [42, 7]
        assert to_tasks(g) == tasks

    def test_app_builders_round_trip(self):
        for app in sorted(taskgraph.APPS):
            g = taskgraph.build_ir(app, Interconnect.LISA, n_pes=16)
            assert to_tasks(g) == taskgraph.build(app, Interconnect.LISA,
                                                  n_pes=16)

    def test_scalar_vs_tuple_dst_distinguished(self):
        tasks = [Task(0, "move", src=0, dst=1),
                 Task(1, "move", src=0, dst=(1,))]
        back = to_tasks(from_tasks(tasks))
        assert back[0].dst == 1
        assert back[1].dst == (1,)


class TestValidation:
    def test_cycle_names_uids(self):
        tasks = [Task(10, "op", deps=(11,), pe=0, duration=1.0),
                 Task(11, "op", deps=(10,), pe=0, duration=1.0),
                 Task(12, "op", pe=0, duration=1.0)]
        with pytest.raises(ValueError, match=r"cycle.*10.*11"):
            from_tasks(tasks).validate()

    def test_dangling_dep_names_offenders(self):
        tasks = [Task(0, "op", pe=0, duration=1.0),
                 Task(1, "op", deps=(99,), pe=0, duration=1.0)]
        with pytest.raises(ValueError, match=r"dangling.*task 1.*99"):
            from_tasks(tasks)

    def test_duplicate_uids_rejected(self):
        tasks = [Task(3, "op", pe=0, duration=1.0),
                 Task(3, "op", pe=1, duration=1.0)]
        with pytest.raises(ValueError, match=r"duplicate.*3"):
            from_tasks(tasks)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown task kind"):
            from_tasks([Task(0, "teleport", pe=0)])

    def test_self_dependency_is_a_cycle(self):
        with pytest.raises(ValueError, match="cycle"):
            from_tasks([Task(0, "op", deps=(0,), pe=0)]).validate()

    def test_op_without_pe_rejected(self):
        # the legacy engine raised TypeError deep in the loop; the validator
        # must reject up front instead of scheduling a sentinel-derived PE
        with pytest.raises(ValueError, match=r"ops without a pe.*\[7\]"):
            from_tasks([Task(7, "op", duration=5.0)]).validate()

    def test_move_without_src_rejected(self):
        with pytest.raises(ValueError, match=r"moves without a src.*\[3\]"):
            from_tasks([Task(3, "move", dst=1, rows=2)]).validate()

    def test_move_without_destinations_rejected(self):
        b = GraphBuilder()
        b.move(0, ())
        with pytest.raises(ValueError, match="without destinations"):
            b.build().validate()

    def test_valid_graph_passes(self):
        from_tasks(diamond_tasks()).validate()


class TestDerivedStructure:
    def test_levels(self):
        g = from_tasks(diamond_tasks())
        assert g.levels().tolist() == [0, 1, 1, 2]

    def test_successors_mirror_deps(self):
        g = from_tasks(diamond_tasks())
        indptr, flat = g.successors()
        assert flat[indptr[0]:indptr[1]].tolist() == [1, 2]
        assert flat[indptr[1]:indptr[2]].tolist() == [3]
        assert flat[indptr[3]:indptr[4]].tolist() == []

    def test_empty_graph(self):
        g = from_tasks([])
        g.validate()
        assert g.n == 0 and g.levels().tolist() == []


class TestMaterialize:
    def test_symbolic_durations_fill_per_mode(self):
        b = GraphBuilder()
        u = b.op(0, op_class="mul")
        b.op(1, (u,), op_class="add")
        g = b.build()
        from repro.core import pluto
        for mode in Interconnect:
            m = materialize(g, mode)
            assert m.duration[0] == pluto.op32_latency_ns("mul", mode)
            assert m.duration[1] == pluto.op32_latency_ns("add", mode)
        assert (g.duration == 0).all()      # structural graph untouched

    def test_explicit_durations_pass_through(self):
        g = from_tasks([Task(0, "op", pe=0, duration=123.0)])
        assert materialize(g, Interconnect.LISA) is g

    def test_structural_cache_shared_across_modes(self):
        s1 = taskgraph.structural("mm", n=10, n_pes=16)
        s2 = taskgraph.structural("mm", n=10, n_pes=16)
        assert s1 is s2
        a = taskgraph.build_ir("mm", Interconnect.LISA, n=10)
        b = taskgraph.build_ir("mm", Interconnect.SHARED_PIM, n=10)
        assert a.dep_pos is b.dep_pos        # structure shared
        assert not np.array_equal(a.duration, b.duration)
