"""Batched serving example: prefill + lockstep decode with KV caches.

Serves a reduced glm4-9b (GQA kv=2) and a reduced falcon-mamba-7b (pure SSM
— O(1) decode state) side by side to show the engine is family-agnostic.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import model as model_lib
from repro.serve.engine import Engine, ServeConfig


def serve(arch: str, n_requests: int = 4, max_new: int = 24):
    cfg = registry.get(arch).reduced()
    model = model_lib.build(cfg)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params,
                    ServeConfig(max_batch=n_requests, max_len=128,
                                temperature=0.7, seed=13))
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(2, cfg.vocab_size,
                                 size=int(rng.integers(4, 16))))
               for _ in range(n_requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    new_tokens = sum(len(o) - len(p) for o, p in zip(outs, prompts))
    print(f"[{arch}] {n_requests} requests, {new_tokens} new tokens "
          f"in {dt:.2f}s ({new_tokens/dt:.1f} tok/s on CPU)")
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"  req{i}: ...{o[len(p):][:8]}")


if __name__ == "__main__":
    serve("glm4-9b")
    serve("falcon-mamba-7b")
