"""The paper's NTT butterfly pipeline (Fig 4a), end to end.

Builds the butterfly dataflow, schedules it under LISA and Shared-PIM
(showing the STALL -> NOP transformation per stage), and then actually
computes the same NTT bit-exactly on the pLUTo LUT-ALU, verifying against
an O(n^2) DFT oracle over Z_q.

Run:  PYTHONPATH=src python examples/pim_pipeline.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import executor, scheduler, taskgraph
from repro.core.pluto import Interconnect


def schedule_side():
    print("== NTT (n=512) on 16 subarray-PEs ==")
    res = {m: scheduler.schedule(taskgraph.build("ntt", m, n=512), m)
           for m in Interconnect}
    lisa, sp = res[Interconnect.LISA], res[Interconnect.SHARED_PIM]
    print(f"  LISA:       {lisa.makespan_ns/1e3:8.1f} us "
          f"({lisa.n_moves} moves stall {lisa.stall_ns/1e3:.1f} us of PE "
          f"time)")
    print(f"  Shared-PIM: {sp.makespan_ns/1e3:8.1f} us "
          f"(same moves ride the BK-bus: stall = {sp.stall_ns:.0f} ns)")
    print(f"  improvement {(1 - sp.makespan_ns/lisa.makespan_ns)*100:.1f}% "
          f"(paper: 31%)")


def functional_side():
    q, n = 7681, 64
    root = next(c for c in range(2, q)
                if pow(c, n, q) == 1 and pow(c, n // 2, q) != 1)
    rng = np.random.default_rng(0)
    x = rng.integers(0, q, n, dtype=np.uint32)
    got = np.asarray(executor.ntt(jnp.asarray(x), q=q, root=root))
    want = executor.ntt_oracle(x, q=q, root=root)
    assert (got == want).all()
    print(f"\n== functional NTT-{n} over Z_{q} on the LUT-ALU ==")
    print(f"  input[:6]  = {x[:6]}")
    print(f"  output[:6] = {got[:6]}")
    print("  bit-exact vs O(n^2) DFT oracle: OK")


if __name__ == "__main__":
    schedule_side()
    functional_side()
