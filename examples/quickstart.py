"""Quickstart: the paper in five minutes.

1. Reproduce Table II (copy latency/energy) from the command-level models.
2. Run the Fig-8 matrix-multiply workload through the cycle-accurate
   scheduler under both interconnects and see the concurrency win.
3. Compute with the pLUTo LUT-ALU (bit-exact in-DRAM-style arithmetic).
4. Train a reduced LM for a few steps with the framework's trainer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import copy_models, scheduler, taskgraph
from repro.core.pluto import Interconnect
from repro.core import pluto_alu


def copy_latency_demo():
    print("== Table II: 8KB inter-subarray copy ==")
    for name, (lat, en) in copy_models.table2().items():
        print(f"  {name:28s} {lat:9.2f} ns   {en:6.3f} uJ")
    bc = copy_models.sharedpim_broadcast(dests=(1, 2, 3, 4))
    print(f"  broadcast to 4 subarrays     {bc.latency_ns:9.2f} ns "
          f"(vs {4 * 52.75:.2f} serial)")


def scheduler_demo():
    print("\n== Fig 8: matrix multiply, LISA vs Shared-PIM ==")
    res = {m: scheduler.schedule(taskgraph.build("mm", m, n=200), m)
           for m in Interconnect}
    lisa, sp = res[Interconnect.LISA], res[Interconnect.SHARED_PIM]
    print(f"  LISA:       {lisa.makespan_ns/1e3:9.1f} us  "
          f"(stalled {lisa.stall_ns/1e3:.1f} us of PE time)")
    print(f"  Shared-PIM: {sp.makespan_ns/1e3:9.1f} us  "
          f"(stall -> NOP; bus busy {sp.move_busy_ns/1e3:.1f} us)")
    print(f"  improvement: {(1 - sp.makespan_ns/lisa.makespan_ns)*100:.1f}% "
          f"(paper: 40%)")


def lut_alu_demo():
    print("\n== pLUTo LUT-ALU: arithmetic as table lookups ==")
    x = jnp.asarray(np.array([123456789, 7, 2**31], dtype=np.uint32))
    y = jnp.asarray(np.array([987654321, 6, 2], dtype=np.uint32))
    print(f"  add: {np.asarray(pluto_alu.pluto_add(x, y))}")
    print(f"  mul: {np.asarray(pluto_alu.pluto_mul(x, y))}")
    print("  (bit-identical to uint32 arithmetic, computed via 4-bit LUTs)")


def device_demo():
    print("\n== Device scale: mm across a 2-channel x 4-bank device ==")
    from repro import device
    geom = device.DeviceGeometry(channels=2, banks_per_channel=4,
                                 bank_groups_per_channel=2)
    print(f"  geometry: {geom.describe()}")
    for policy in device.POLICIES:
        res = {}
        for m in Interconnect:
            tasks = device.build_partitioned("mm", m, geom, policy=policy,
                                             n=100)
            res[m.value] = device.schedule(tasks, m, geom)
        sp = res["shared_pim"]
        print(f"  {policy:20s} improvement {device.improvement(res)*100:5.1f}%"
              f"  cross-bank rows {sp.cross_rows:6d}"
              f"  (LISA stalled {res['lisa'].stall_ns/1e3:.0f} us of PE time)")


def train_demo():
    print("\n== Train a reduced granite-3-2b for 10 steps ==")
    from repro.launch.train import main as train_main
    train_main(["--arch", "granite-3-2b", "--smoke", "--steps", "10",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", "/tmp/repro_quickstart_ckpt"])


if __name__ == "__main__":
    copy_latency_demo()
    scheduler_demo()
    lut_alu_demo()
    device_demo()
    train_demo()
