"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the full framework stack — config system, sharded train step, AdamW,
synthetic data pipeline, fault-tolerant trainer with checkpointing — on a
gemma3-flavoured config sized to ~100M params.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import train_step as ts
from repro.train.trainer import Trainer, TrainerConfig


def config_100m():
    base = registry.get("gemma3-1b")
    return dataclasses.replace(
        base, name="gemma3-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32_768,
        sliding_window=256, local_global_every=3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = config_100m()
    model = model_lib.build(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params: {n_params/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=args.steps,
                                warmup_steps=args.steps // 20)
    state = ts.make_train_state(model, opt_cfg, jax.random.key(0))
    step = jax.jit(ts.make_train_step(model, opt_cfg), donate_argnums=(0,))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    trainer = Trainer(step, state, data_cfg, "/tmp/repro_train_lm_ckpt",
                      TrainerConfig(total_steps=args.steps,
                                    checkpoint_every=100,
                                    log_every=20))
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{out['final_step']} steps")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
