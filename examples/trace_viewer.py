"""Record the paper's timeline argument as Perfetto-loadable traces.

The headline numbers say Shared-PIM beats LISA; the *traces* show why.
This example records a tiled matmul and an MoE decode step under both
interconnects and dumps each schedule as Chrome trace-event JSON — one
track per bank PE, BK-bus, tx/rx shared row, and bus.  Load a Shared-PIM
trace next to its LISA twin at https://ui.perfetto.dev: the LISA PE
tracks gap for every inter-bank span (circuit switching blocks the source
and destination banks end to end), the Shared-PIM tracks keep computing
while the rows drain/transit/fill through the shared-row tracks.  The
``power`` process renders the same schedule as windowed watt counters —
one track per bank and bus plus the device total — so the paper's
transfer-energy claim shows up as a visibly lower, shorter power curve.

Equivalent CLI: ``PYTHONPATH=src python -m repro.obs``.

Run: ``PYTHONPATH=src python examples/trace_viewer.py``
"""

from repro.obs.viewer import main

if __name__ == "__main__":
    raise SystemExit(main())
